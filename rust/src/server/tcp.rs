//! TCP front end: thread-per-connection server over [`super::LocalCluster`].

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::protocol::{format_values, parse_request, FaultCmd, Request};
use super::LocalCluster;
use crate::error::Result;

/// A running TCP server (owns its listener thread).
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve `cluster`.
    pub fn start(addr: &str, cluster: Arc<LocalCluster>) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            // workers are detached: a connection blocked in read would
            // otherwise wedge shutdown. The per-stream read timeout below
            // bounds their lifetime after the listener stops.
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let cluster = cluster.clone();
                        let stop = stop2.clone();
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, &cluster, &stop);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Server { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting and join the listener thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Apply a `FAULT` admin command to the cluster's chaos fabric.
fn apply_fault(cluster: &LocalCluster, cmd: FaultCmd) -> String {
    let fabric = cluster.fabric();
    let nodes = cluster.node_count();
    match cmd {
        FaultCmd::Crash { node } if node < nodes => {
            fabric.crash(node);
            "OK\n".to_string()
        }
        FaultCmd::Crash { node } => format!("ERR node {node} out of range\n"),
        FaultCmd::Partition { left, right } => {
            if let Some(bad) = left.iter().chain(&right).find(|&&n| n >= nodes) {
                format!("ERR node {bad} out of range\n")
            } else {
                fabric.partition_groups(&left, &right);
                "OK\n".to_string()
            }
        }
        FaultCmd::Drop { ppm } => {
            fabric.set_drop_prob(f64::from(ppm) / 1_000_000.0);
            "OK\n".to_string()
        }
        FaultCmd::Delay { us } => {
            fabric.set_extra_delay_us(us);
            "OK\n".to_string()
        }
    }
}

/// Apply a `HEAL` admin command: recover one node, or reset every fault
/// axis and drain parked hints.
fn apply_heal(cluster: &LocalCluster, node: Option<usize>) -> String {
    match node {
        Some(n) if n < cluster.node_count() => {
            cluster.fabric().recover(n);
            cluster.drain_hints();
            "OK\n".to_string()
        }
        Some(n) => format!("ERR node {n} out of range\n"),
        None => {
            cluster.fabric().heal_all();
            cluster.drain_hints();
            "OK\n".to_string()
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    cluster: &LocalCluster,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    // the listener is non-blocking; make sure the accepted stream is not
    // (some platforms propagate O_NONBLOCK to accepted sockets)
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true).ok();
    // bounded reads so workers notice server shutdown
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client hung up
            Ok(_) if line.ends_with('\n') => {}
            Ok(_) => continue, // partial line; keep accumulating
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // partial data (if any) stays in `line`
                if stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e),
        }
        if line.trim().is_empty() {
            line.clear();
            continue;
        }
        let reply = match parse_request(&line) {
            Ok(Request::Get { key }) => match cluster.get(&key) {
                Ok(ans) => format_values(&ans.values, &ans.context),
                Err(e) => format!("ERR {e}\n"),
            },
            Ok(Request::Put { key, value, context }) => {
                match cluster.put(&key, value, &context) {
                    Ok(()) => "OK\n".to_string(),
                    Err(e) => format!("ERR {e}\n"),
                }
            }
            Ok(Request::Stats) => format!(
                "STATS nodes={} shards={} metadata_bytes={} hints={}\n",
                cluster.node_count(),
                cluster.shard_count(),
                cluster.metadata_bytes(),
                cluster.pending_hints()
            ),
            Ok(Request::Fault(cmd)) => apply_fault(cluster, cmd),
            Ok(Request::Heal { node }) => apply_heal(cluster, node),
            Ok(Request::Quit) => {
                stream.write_all(b"BYE\n")?;
                return Ok(());
            }
            Err(e) => format!("ERR {e}\n"),
        };
        stream.write_all(reply.as_bytes())?;
        line.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::protocol::hex_encode;
    use std::io::{BufRead, BufReader, Write};

    fn client(addr: std::net::SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
        let stream = TcpStream::connect(addr).unwrap();
        (BufReader::new(stream.try_clone().unwrap()), stream)
    }

    fn send(w: &mut TcpStream, line: &str) {
        w.write_all(line.as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
    }

    fn recv(r: &mut BufReader<TcpStream>) -> String {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        line.trim_end().to_string()
    }

    #[test]
    fn end_to_end_get_put_siblings() {
        let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
        let server = Server::start("127.0.0.1:0", cluster).unwrap();
        let (mut r, mut w) = client(server.addr());

        // blind write twice -> siblings
        send(&mut w, &format!("PUT k {}", hex_encode(b"v1")));
        assert_eq!(recv(&mut r), "OK");
        send(&mut w, &format!("PUT k {}", hex_encode(b"v2")));
        assert_eq!(recv(&mut r), "OK");

        send(&mut w, "GET k");
        let header = recv(&mut r);
        assert!(header.starts_with("VALUES 2 "), "{header}");
        let ctx = header.split_whitespace().nth(2).unwrap().to_string();
        let v1 = recv(&mut r);
        let v2 = recv(&mut r);
        assert!(v1.starts_with("VALUE ") && v2.starts_with("VALUE "));

        // contextful write supersedes both siblings
        send(&mut w, &format!("PUT k {} {}", hex_encode(b"merged"), ctx));
        assert_eq!(recv(&mut r), "OK");
        send(&mut w, "GET k");
        let header = recv(&mut r);
        assert!(header.starts_with("VALUES 1 "), "{header}");
        assert_eq!(recv(&mut r), format!("VALUE {}", hex_encode(b"merged")));

        send(&mut w, "STATS");
        assert!(recv(&mut r).starts_with("STATS nodes=3"));
        send(&mut w, "QUIT");
        assert_eq!(recv(&mut r), "BYE");
        server.shutdown();
    }

    #[test]
    fn protocol_errors_are_reported_not_fatal() {
        let cluster = Arc::new(LocalCluster::new(2, 2, 1, 1).unwrap());
        let server = Server::start("127.0.0.1:0", cluster).unwrap();
        let (mut r, mut w) = client(server.addr());
        send(&mut w, "BOGUS");
        assert!(recv(&mut r).starts_with("ERR "));
        // connection still usable
        send(&mut w, &format!("PUT a {}", hex_encode(b"x")));
        assert_eq!(recv(&mut r), "OK");
        server.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
        let server = Server::start("127.0.0.1:0", cluster).unwrap();
        let addr = server.addr();
        let mut handles = Vec::new();
        for t in 0..4 {
            handles.push(std::thread::spawn(move || {
                let (mut r, mut w) = client(addr);
                for i in 0..20 {
                    send(&mut w, &format!("PUT t{t}k{i} {}", hex_encode(b"data")));
                    assert_eq!(recv(&mut r), "OK");
                }
                for i in 0..20 {
                    send(&mut w, &format!("GET t{t}k{i}"));
                    let header = recv(&mut r);
                    assert!(header.starts_with("VALUES 1 "), "{header}");
                    let _ = recv(&mut r);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        server.shutdown();
    }
}
