//! TCP front end over [`super::LocalCluster`], with two serve loops
//! behind one [`Server`] facade.
//!
//! Each connection negotiates its protocol by its first bytes: a
//! [`protocol::MAGIC`] preamble selects the length-prefixed **binary
//! protocol v2** (acknowledged with an `OP_HELLO_ACK` frame); anything
//! else falls back to the legacy line-based text protocol, so old
//! clients keep working against a new server unchanged. Request
//! *execution* is shared between the serve loops ([`super::ops`]), so
//! both speak an identical wire protocol.
//!
//! [`ServeMode::Reactor`] (the default) is the readiness-based loop: a
//! `poll(2)` reactor owning nonblocking connection states, a small
//! worker pool executing requests, and per-connection frame pipelining
//! — see [`super::reactor`] for the state machine. Shutdown drains
//! in-flight requests and joins every thread deterministically.
//!
//! [`ServeMode::Threaded`] is the legacy thread-per-connection loop,
//! kept as the baseline the connection-scalability bench compares
//! against (`benches/conn.rs`). It is hardened here: connection threads
//! are joined on shutdown (no detached worker can outlive
//! [`Server::shutdown`] holding the cluster `Arc` mid-WAL-write), frame
//! payloads are read into a capped-growth buffer instead of trusting
//! the attacker-controlled header with a 16 MiB pre-allocation, and
//! buffered text lines are capped at [`protocol::MAX_TEXT_LINE`].

use std::io::{BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use super::ops::{self, TextReply};
use super::protocol;
use super::LocalCluster;
use crate::error::{Error, Result};
use crate::kernel::mechs::DvvMech;
use crate::store::StorageBackend;

/// Incremental growth step for frame-payload reads: a frame body is
/// read (and its buffer grown) this many bytes at a time, so a hostile
/// header promising [`protocol::MAX_FRAME_LEN`] bytes costs the server
/// at most one chunk until the payload actually arrives.
pub(crate) const READ_CHUNK: usize = 64 * 1024;

/// Stack size for thread-per-connection workers. The default 8 MiB
/// would cap a 10k-connection bench at the memory limit long before the
/// scheduler does; connection handlers are shallow.
const CONN_STACK: usize = 256 * 1024;

/// How [`Server`] turns sockets into executed requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeMode {
    /// One blocking thread per connection: the legacy loop, kept as the
    /// baseline the connection-scalability bench compares the reactor
    /// against.
    Threaded,
    /// Readiness-based `poll(2)` reactor + worker pool, with
    /// per-connection binary-frame pipelining (the default). On
    /// non-unix targets this falls back to [`ServeMode::Threaded`].
    Reactor {
        /// Worker threads executing requests; `0` sizes the pool from
        /// available parallelism (clamped to `2..=8`).
        workers: usize,
    },
}

/// Options for [`Server::start_with`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeOptions {
    /// Which serve loop to run.
    pub mode: ServeMode,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions { mode: ServeMode::Reactor { workers: 0 } }
    }
}

/// The running serve loop behind a [`Server`].
enum Inner {
    Threaded {
        stop: Arc<AtomicBool>,
        listener: std::thread::JoinHandle<()>,
        conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    },
    #[cfg(unix)]
    Reactor(super::reactor::Handle),
}

/// A running TCP server (owns every thread it spawned; shutdown joins
/// them all, so no worker holding the cluster `Arc` outlives it).
pub struct Server {
    addr: std::net::SocketAddr,
    inner: Option<Inner>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve
    /// `cluster` with the default options (reactor mode) — any storage
    /// backend, in-memory or durable (`serve --data-dir` passes a
    /// [`DurableBackend`](crate::store::DurableBackend)-backed cluster).
    pub fn start<B: StorageBackend<DvvMech>>(
        addr: &str,
        cluster: Arc<LocalCluster<B>>,
    ) -> Result<Server> {
        Server::start_with(addr, cluster, ServeOptions::default())
    }

    /// Bind `addr` and serve `cluster` with an explicit [`ServeMode`].
    pub fn start_with<B: StorageBackend<DvvMech>>(
        addr: &str,
        cluster: Arc<LocalCluster<B>>,
        options: ServeOptions,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let inner = match options.mode {
            #[cfg(unix)]
            ServeMode::Reactor { workers } => {
                Inner::Reactor(super::reactor::spawn(listener, cluster, workers)?)
            }
            #[cfg(not(unix))]
            ServeMode::Reactor { .. } => start_threaded(listener, cluster),
            ServeMode::Threaded => start_threaded(listener, cluster),
        };
        Ok(Server { addr: local, inner: Some(inner) })
    }

    /// The bound address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting, drain in-flight requests, and join every serving
    /// thread. When this returns, no server thread still holds the
    /// cluster `Arc` — a caller may immediately tear down shared state
    /// (delete a data dir, assert `Arc::strong_count`).
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        match self.inner.take() {
            Some(Inner::Threaded { stop, listener, conns }) => {
                stop.store(true, Ordering::Relaxed);
                let _ = listener.join();
                // connection threads notice `stop` within one read
                // timeout; joining them (instead of detaching) is what
                // makes teardown safe for callers that delete the data
                // dir right after shutdown
                let workers: Vec<_> = std::mem::take(&mut *conns.lock().unwrap());
                for h in workers {
                    let _ = h.join();
                }
            }
            #[cfg(unix)]
            Some(Inner::Reactor(handle)) => handle.shutdown(),
            None => {}
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// Spawn the legacy thread-per-connection loop: an accept thread plus a
/// join-on-shutdown registry of connection threads.
fn start_threaded<B: StorageBackend<DvvMech>>(
    listener: TcpListener,
    cluster: Arc<LocalCluster<B>>,
) -> Inner {
    let stop = Arc::new(AtomicBool::new(false));
    let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
    let stop2 = Arc::clone(&stop);
    let conns2 = Arc::clone(&conns);
    let handle = std::thread::spawn(move || {
        while !stop2.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let cluster = Arc::clone(&cluster);
                    let stop = Arc::clone(&stop2);
                    let mut registry = conns2.lock().unwrap();
                    // reap finished handles so the registry tracks live
                    // connections, not connection history
                    registry.retain(|h| !h.is_finished());
                    let spawned = std::thread::Builder::new()
                        .name("dvv-conn".into())
                        .stack_size(CONN_STACK)
                        .spawn(move || {
                            let _ = handle_conn(stream, &cluster, &stop);
                        });
                    // on spawn failure (thread exhaustion): shed the
                    // connection instead of killing the accept loop
                    if let Ok(h) = spawned {
                        registry.push(h);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    });
    Inner::Threaded { stop, listener: handle, conns }
}

/// Read one byte, looping on read timeouts until data arrives, the peer
/// hangs up (`None`), or the server shuts down (`None`).
fn read_byte(r: &mut impl Read, stop: &AtomicBool) -> Result<Option<u8>> {
    let mut b = [0u8; 1];
    loop {
        match r.read(&mut b) {
            Ok(0) => return Ok(None),
            Ok(_) => return Ok(Some(b[0])),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) {
                    return Ok(None);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
}

/// Fill `buf` completely, tolerating read timeouts. `Ok(false)` = clean
/// end of stream (or shutdown) before the first byte when `eof_ok`;
/// truncation mid-buffer is always an error.
fn read_full(r: &mut impl Read, buf: &mut [u8], stop: &AtomicBool, eof_ok: bool) -> Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 && eof_ok {
                    return Ok(false);
                }
                return Err(Error::Protocol("connection closed mid-frame".into()));
            }
            Ok(n) => filled += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) {
                    if filled == 0 && eof_ok {
                        return Ok(false);
                    }
                    return Err(Error::Protocol("server shutting down mid-frame".into()));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(true)
}

/// Read one v2 frame into `body` (opcode + payload), reusing the buffer
/// across frames. The body grows in [`READ_CHUNK`] steps, each step
/// allocated only after the previous one's bytes actually arrived — the
/// attacker-controlled length field never picks an allocation size
/// (the same hostile-pre-allocation class `decode_vv` was fixed for).
/// `Ok(false)` = clean disconnect (or shutdown) before a header.
fn read_frame_server(r: &mut impl Read, stop: &AtomicBool, body: &mut Vec<u8>) -> Result<bool> {
    let mut header = [0u8; 4];
    if !read_full(r, &mut header, stop, true)? {
        return Ok(false);
    }
    let len = protocol::frame_len(header)?;
    body.clear();
    // one oversized frame must not pin its capacity for the rest of the
    // connection
    body.shrink_to(READ_CHUNK);
    while body.len() < len {
        let step = (len - body.len()).min(READ_CHUNK);
        let start = body.len();
        body.resize(start + step, 0);
        read_full(r, &mut body[start..], stop, false)?;
    }
    Ok(true)
}

fn handle_conn<B: StorageBackend<DvvMech>>(
    mut stream: TcpStream,
    cluster: &LocalCluster<B>,
    stop: &AtomicBool,
) -> Result<()> {
    // the listener is non-blocking; make sure the accepted stream is not
    // (some platforms propagate O_NONBLOCK to accepted sockets)
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true).ok();
    // bounded reads so workers notice server shutdown; bounded writes so
    // a stalled peer cannot wedge the join-on-shutdown teardown
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    stream.set_write_timeout(Some(std::time::Duration::from_secs(1)))?;
    let mut reader = BufReader::new(stream.try_clone()?);

    // transport negotiation: sniff byte by byte, bailing to the text
    // protocol on the first byte that diverges from the magic (so a
    // short text command is answered without waiting for more input)
    let mut probe: Vec<u8> = Vec::with_capacity(protocol::MAGIC.len());
    while probe.len() < protocol::MAGIC.len() && protocol::MAGIC.starts_with(&probe) {
        match read_byte(&mut reader, stop)? {
            Some(b) => probe.push(b),
            None => return Ok(()), // hung up before the first request
        }
    }
    let served = if probe == protocol::MAGIC {
        serve_binary(&mut reader, &mut stream, cluster, stop)
    } else {
        serve_text(&mut reader, &mut stream, cluster, stop, probe)
    };
    // bounded drain of unread input before the socket drops: closing
    // with bytes still queued (a line past the cap, frames pipelined
    // after QUIT) would RST, and Linux purges the peer's receive queue
    // on RST — discarding the final BYE/ERR reply before it is read
    if served.is_ok() {
        drain_unread(&mut reader, stop);
    }
    served
}

/// Read and discard input until the peer's EOF, a short deadline, or
/// shutdown — see the call site in [`handle_conn`] for why.
fn drain_unread(r: &mut impl Read, stop: &AtomicBool) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_millis(250);
    let mut chunk = [0u8; 4096];
    while std::time::Instant::now() < deadline && !stop.load(Ordering::Relaxed) {
        match r.read(&mut chunk) {
            Ok(0) => break,
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => break,
        }
    }
}

/// The legacy line-based text protocol. `acc` seeds the input buffer
/// with whatever the negotiation sniff already consumed.
fn serve_text<B: StorageBackend<DvvMech>>(
    reader: &mut BufReader<TcpStream>,
    stream: &mut TcpStream,
    cluster: &LocalCluster<B>,
    stop: &AtomicBool,
    mut acc: Vec<u8>,
) -> Result<()> {
    let mut chunk = [0u8; 4096];
    loop {
        // drain every complete line already buffered, parsing each in
        // place from a split borrow of `acc` (no per-line Vec); the
        // consumed prefix is drained once per batch below
        let mut consumed = 0;
        while let Some(nl) = acc[consumed..].iter().position(|&b| b == b'\n') {
            let end = consumed + nl;
            if nl > protocol::MAX_TEXT_LINE {
                // a complete line obeys the same cap as a buffered
                // partial one — the newline can arrive in the same read
                // chunk that crossed the cap
                stream.write_all(b"ERR line too long\n")?;
                return Ok(());
            }
            let line = String::from_utf8_lossy(&acc[consumed..end]);
            if line.trim().is_empty() {
                consumed = end + 1;
                continue;
            }
            let reply = ops::exec_text_line(cluster, &line);
            consumed = end + 1;
            match reply {
                TextReply::Line(text) => stream.write_all(text.as_bytes())?,
                TextReply::Bye => {
                    stream.write_all(b"BYE\n")?;
                    return Ok(());
                }
            }
        }
        if consumed > 0 {
            acc.drain(..consumed);
        }
        // what remains is one partial line; past the cap it can never
        // complete legally — answer and close instead of buffering a
        // newline-less client without bound
        if acc.len() > protocol::MAX_TEXT_LINE {
            stream.write_all(b"ERR line too long\n")?;
            return Ok(());
        }
        // need more input
        match reader.read(&mut chunk) {
            Ok(0) => return Ok(()), // client hung up
            Ok(n) => acc.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
}

/// The binary protocol v2 loop (the magic preamble is already consumed).
fn serve_binary<B: StorageBackend<DvvMech>>(
    reader: &mut BufReader<TcpStream>,
    stream: &mut TcpStream,
    cluster: &LocalCluster<B>,
    stop: &AtomicBool,
) -> Result<()> {
    // hello tail: requested version + newline terminator
    let Some(version) = read_byte(reader, stop)? else { return Ok(()) };
    let Some(terminator) = read_byte(reader, stop)? else { return Ok(()) };
    if terminator != b'\n' {
        // enforce the documented preamble: silently eating a stray byte
        // here would desynchronize every following frame
        let _ = protocol::write_frame(
            stream,
            protocol::OP_ERR,
            b"malformed hello: missing newline after version byte",
        );
        return Ok(());
    }
    if version != protocol::VERSION {
        // clean version-skew rejection: one ERR frame, then close
        let msg = format!(
            "unsupported protocol version {version} (server speaks {})",
            protocol::VERSION
        );
        let _ = protocol::write_frame(stream, protocol::OP_ERR, msg.as_bytes());
        return Ok(());
    }
    protocol::write_frame(stream, protocol::OP_HELLO_ACK, &[protocol::VERSION])?;
    let mut body = Vec::new();
    loop {
        match read_frame_server(reader, stop, &mut body) {
            Ok(true) => {}
            Ok(false) => return Ok(()), // clean disconnect / shutdown
            Err(e) => {
                // broken framing (zero/oversized length, truncation): the
                // byte stream can no longer be trusted — one final ERR
                // frame, then drop the connection
                let _ =
                    protocol::write_frame(stream, protocol::OP_ERR, e.to_string().as_bytes());
                return Ok(());
            }
        }
        let reply = ops::exec_bin_request(cluster, body[0], &body[1..]);
        protocol::write_frame(stream, reply.opcode, &reply.payload)?;
        if reply.close {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::protocol::hex_encode;
    use std::io::{BufRead, BufReader, Write};

    fn client(addr: std::net::SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
        let stream = TcpStream::connect(addr).unwrap();
        (BufReader::new(stream.try_clone().unwrap()), stream)
    }

    fn send(w: &mut TcpStream, line: &str) {
        w.write_all(line.as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
    }

    fn recv(r: &mut BufReader<TcpStream>) -> String {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        line.trim_end().to_string()
    }

    /// Both serve loops, so every scenario runs against each.
    const MODES: [ServeMode; 2] =
        [ServeMode::Reactor { workers: 2 }, ServeMode::Threaded];

    fn start_mode(
        cluster: Arc<LocalCluster>,
        mode: ServeMode,
    ) -> Server {
        Server::start_with("127.0.0.1:0", cluster, ServeOptions { mode }).unwrap()
    }

    #[test]
    fn end_to_end_get_put_siblings() {
        for mode in MODES {
            let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
            let server = start_mode(cluster, mode);
            let (mut r, mut w) = client(server.addr());

            // blind write twice -> siblings
            send(&mut w, &format!("PUT k {}", hex_encode(b"v1")));
            assert_eq!(recv(&mut r), "OK");
            send(&mut w, &format!("PUT k {}", hex_encode(b"v2")));
            assert_eq!(recv(&mut r), "OK");

            send(&mut w, "GET k");
            let header = recv(&mut r);
            assert!(header.starts_with("VALUES 2 "), "{header}");
            let ctx = header.split_whitespace().nth(2).unwrap().to_string();
            let v1 = recv(&mut r);
            let v2 = recv(&mut r);
            assert!(v1.starts_with("VALUE ") && v2.starts_with("VALUE "));

            // contextful write supersedes both siblings
            send(&mut w, &format!("PUT k {} {}", hex_encode(b"merged"), ctx));
            assert_eq!(recv(&mut r), "OK");
            send(&mut w, "GET k");
            let header = recv(&mut r);
            assert!(header.starts_with("VALUES 1 "), "{header}");
            assert_eq!(recv(&mut r), format!("VALUE {}", hex_encode(b"merged")));

            send(&mut w, "STATS");
            assert!(recv(&mut r).starts_with("STATS nodes=3"));
            send(&mut w, "QUIT");
            assert_eq!(recv(&mut r), "BYE");
            server.shutdown();
        }
    }

    #[test]
    fn protocol_errors_are_reported_not_fatal() {
        for mode in MODES {
            let cluster = Arc::new(LocalCluster::new(2, 2, 1, 1).unwrap());
            let server = start_mode(cluster, mode);
            let (mut r, mut w) = client(server.addr());
            send(&mut w, "BOGUS");
            assert!(recv(&mut r).starts_with("ERR "));
            // connection still usable
            send(&mut w, &format!("PUT a {}", hex_encode(b"x")));
            assert_eq!(recv(&mut r), "OK");
            server.shutdown();
        }
    }

    #[test]
    fn overlong_text_line_is_rejected_and_closed() {
        for mode in MODES {
            let cluster = Arc::new(LocalCluster::new(2, 2, 1, 1).unwrap());
            let server = start_mode(cluster, mode);
            let (mut r, mut w) = client(server.addr());
            // a newline-less flood past the cap: the old loop buffered
            // this indefinitely
            let blob = vec![b'x'; protocol::MAX_TEXT_LINE + 8192];
            // the server closes mid-flood; a late write may see EPIPE
            let _ = w.write_all(&blob);
            let mut reply = String::new();
            r.read_line(&mut reply).unwrap();
            assert_eq!(reply.trim_end(), "ERR line too long");
            // then EOF: the connection is closed, not left draining
            let mut rest = Vec::new();
            let _ = std::io::Read::read_to_end(&mut r, &mut rest);
            assert!(rest.is_empty(), "connection must close after the cap reply");
            server.shutdown();
        }
    }

    #[test]
    fn oversized_complete_text_line_is_rejected() {
        // unlike the flood above, this line *does* end in a newline —
        // and the newline can land in the same read chunk that crossed
        // the cap, so the complete-line path must enforce the cap too
        // (both serve loops used to dispatch such a line)
        for mode in MODES {
            let cluster = Arc::new(LocalCluster::new(2, 2, 1, 1).unwrap());
            let server = start_mode(cluster, mode);
            let (mut r, mut w) = client(server.addr());
            let mut blob = vec![b'x'; protocol::MAX_TEXT_LINE + 100];
            blob.push(b'\n');
            let _ = w.write_all(&blob);
            let mut reply = String::new();
            r.read_line(&mut reply).unwrap();
            assert_eq!(reply.trim_end(), "ERR line too long", "{mode:?}");
            let mut rest = Vec::new();
            let _ = std::io::Read::read_to_end(&mut r, &mut rest);
            assert!(rest.is_empty(), "connection must close after the cap reply");
            server.shutdown();
        }
    }

    #[test]
    fn shutdown_joins_every_connection_worker() {
        for mode in MODES {
            let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
            let server = start_mode(Arc::clone(&cluster), mode);
            // several live connections mid-session
            let mut sessions = Vec::new();
            for i in 0..4 {
                let (mut r, mut w) = client(server.addr());
                send(&mut w, &format!("PUT k{i} {}", hex_encode(b"v")));
                assert_eq!(recv(&mut r), "OK");
                sessions.push((r, w));
            }
            server.shutdown();
            // every serving thread has been joined: nothing but the
            // caller still holds the cluster (a data dir could now be
            // deleted with no worker mid-WAL-write)
            assert_eq!(Arc::strong_count(&cluster), 1, "{mode:?}");
        }
    }

    #[test]
    fn hostile_frame_header_does_not_preallocate() {
        // header claims MAX_FRAME_LEN bytes but the payload never
        // arrives: the read must fail (EOF mid-frame) having grown the
        // buffer by at most one chunk, not the full 16 MiB claim
        let stop = AtomicBool::new(false);
        let mut wire = Vec::new();
        wire.extend_from_slice(&protocol::MAX_FRAME_LEN.to_be_bytes());
        wire.extend_from_slice(&[protocol::OP_GET; 32]); // a dribble of body
        let mut r = std::io::Cursor::new(wire);
        let mut body = Vec::new();
        assert!(read_frame_server(&mut r, &stop, &mut body).is_err());
        assert!(
            body.capacity() <= 2 * READ_CHUNK,
            "allocated {} for an unfulfilled 16 MiB claim",
            body.capacity()
        );

        // an honest small frame still round-trips through the same path
        let mut wire = Vec::new();
        protocol::write_frame(&mut wire, protocol::OP_GET, b"key").unwrap();
        let mut r = std::io::Cursor::new(wire);
        let mut body = Vec::new();
        assert!(read_frame_server(&mut r, &stop, &mut body).unwrap());
        assert_eq!(body[0], protocol::OP_GET);
        assert_eq!(&body[1..], b"key");
    }

    #[test]
    fn text_elastic_ops_bump_epochs_and_sessions_survive() {
        let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
        let server = Server::start("127.0.0.1:0", cluster).unwrap();
        let (mut r, mut w) = client(server.addr());
        send(&mut w, &format!("PUT k {}", hex_encode(b"v")));
        assert_eq!(recv(&mut r), "OK");
        send(&mut w, "STATS");
        let stats = recv(&mut r);
        assert!(stats.contains(" epoch=1"), "{stats}");

        send(&mut w, "JOIN");
        assert_eq!(recv(&mut r), "OK id=3 epoch=2");
        send(&mut w, "TOPOLOGY");
        assert_eq!(recv(&mut r), "TOPOLOGY epoch=2 slots=4 members=0,1,2,3");
        send(&mut w, "DECOMMISSION 0");
        assert_eq!(recv(&mut r), "OK epoch=3");
        send(&mut w, "TOPOLOGY");
        assert_eq!(recv(&mut r), "TOPOLOGY epoch=3 slots=4 members=1,2,3");

        // the same session keeps serving across both epoch bumps
        send(&mut w, "GET k");
        let header = recv(&mut r);
        assert!(header.starts_with("VALUES 1 "), "{header}");
        let _ = recv(&mut r);

        send(&mut w, "DECOMMISSION 9");
        assert!(recv(&mut r).starts_with("ERR "), "unknown node refused");
        server.shutdown();
    }

    #[test]
    fn restart_and_wipe_admin_ops_over_text() {
        let dir = crate::testkit::temp_dir("tcp-restart");
        let cluster = Arc::new(
            LocalCluster::with_data_dir(3, 3, 2, 2, 4, &dir, crate::store::WalOptions::default())
                .unwrap(),
        );
        let server = Server::start("127.0.0.1:0", Arc::clone(&cluster)).unwrap();
        let (mut r, mut w) = client(server.addr());
        for i in 0..10 {
            send(&mut w, &format!("PUT k{i} {}", hex_encode(b"v")));
            assert_eq!(recv(&mut r), "OK");
        }
        send(&mut w, "STATS");
        let stats = recv(&mut r);
        assert!(stats.contains(" wal_bytes="), "{stats}");
        let wal_bytes: u64 = stats
            .rsplit("wal_bytes=")
            .next()
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(wal_bytes > 0, "{stats}");
        // converged 3-way replication (n=3 over 3 nodes): every member
        // holds every key, so the cluster root is the members' common
        // store root — observable (and nonzero) over live TCP
        let merkle_root: u64 = stats
            .rsplit("merkle_root=")
            .next()
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert_ne!(merkle_root, 0, "{stats}");

        // fsync default is every-64 and nothing was explicitly synced,
        // so the crash-restart loses node 1's whole unsynced tail; the
        // wipe empties node 2 outright — only node 0 still holds data
        send(&mut w, "RESTART 1");
        let reply = recv(&mut r);
        assert!(reply.starts_with("OK replayed="), "{reply}");
        send(&mut w, "WIPE 2");
        assert_eq!(recv(&mut r), "OK");
        send(&mut w, "RESTART 99");
        assert!(recv(&mut r).starts_with("ERR "), "out-of-range refused");

        // rejoin: anti-entropy re-delivers from the surviving replica
        // (a GET's answer is fixed at the first R replies in preference
        // order, so without this a key homed on the two emptied nodes
        // would legitimately answer VALUES 0)
        let mut rounds = 0;
        while cluster.anti_entropy_round() > 0 {
            rounds += 1;
            assert!(rounds < 32, "anti-entropy failed to quiesce");
        }
        for i in 0..10 {
            send(&mut w, &format!("GET k{i}"));
            let header = recv(&mut r);
            assert!(header.starts_with("VALUES 1 "), "{header}");
            let _ = recv(&mut r);
        }
        server.shutdown();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn concurrent_clients() {
        for mode in MODES {
            let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
            let server = start_mode(cluster, mode);
            let addr = server.addr();
            let mut handles = Vec::new();
            for t in 0..4 {
                handles.push(std::thread::spawn(move || {
                    let (mut r, mut w) = client(addr);
                    for i in 0..20 {
                        send(&mut w, &format!("PUT t{t}k{i} {}", hex_encode(b"data")));
                        assert_eq!(recv(&mut r), "OK");
                    }
                    for i in 0..20 {
                        send(&mut w, &format!("GET t{t}k{i}"));
                        let header = recv(&mut r);
                        assert!(header.starts_with("VALUES 1 "), "{header}");
                        let _ = recv(&mut r);
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            server.shutdown();
        }
    }
}
