//! TCP front end: thread-per-connection server over [`super::LocalCluster`].
//!
//! Each connection negotiates its protocol by its first bytes: a
//! [`protocol::MAGIC`] preamble selects the length-prefixed **binary
//! protocol v2** (acknowledged with an `OP_HELLO_ACK` frame); anything
//! else falls back to the legacy line-based text protocol, so old
//! clients keep working against a new server unchanged.

use std::io::{BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::protocol::{self, format_values, parse_request, BinRequest, FaultCmd, Request};
use super::LocalCluster;
use crate::api::CausalCtx;
use crate::clocks::Actor;
use crate::error::{Error, Result};
use crate::kernel::mechs::DvvMech;
use crate::store::StorageBackend;

/// A running TCP server (owns its listener thread).
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve `cluster`
    /// — any storage backend, in-memory or durable
    /// (`serve --data-dir` passes a
    /// [`DurableBackend`](crate::store::DurableBackend)-backed cluster).
    pub fn start<B: StorageBackend<DvvMech>>(
        addr: &str,
        cluster: Arc<LocalCluster<B>>,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            // workers are detached: a connection blocked in read would
            // otherwise wedge shutdown. The per-stream read timeout below
            // bounds their lifetime after the listener stops.
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let cluster = cluster.clone();
                        let stop = stop2.clone();
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, &cluster, &stop);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Server { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting and join the listener thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Apply a `FAULT` admin command to the cluster's chaos fabric.
fn apply_fault<B: StorageBackend<DvvMech>>(cluster: &LocalCluster<B>, cmd: FaultCmd) -> String {
    let fabric = cluster.fabric();
    let nodes = cluster.node_count();
    match cmd {
        FaultCmd::Crash { node } if node < nodes => {
            fabric.crash(node);
            "OK\n".to_string()
        }
        FaultCmd::Crash { node } => format!("ERR node {node} out of range\n"),
        FaultCmd::Partition { left, right } => {
            if let Some(bad) = left.iter().chain(&right).find(|&&n| n >= nodes) {
                format!("ERR node {bad} out of range\n")
            } else {
                fabric.partition_groups(&left, &right);
                "OK\n".to_string()
            }
        }
        FaultCmd::Drop { ppm } => {
            fabric.set_drop_prob(f64::from(ppm) / 1_000_000.0);
            "OK\n".to_string()
        }
        FaultCmd::Delay { us } => {
            fabric.set_extra_delay_us(us);
            "OK\n".to_string()
        }
    }
}

/// Apply a `RESTART` admin command: crash-restart one replica's storage
/// (unpersisted state lost, WAL replayed).
fn apply_restart<B: StorageBackend<DvvMech>>(cluster: &LocalCluster<B>, node: usize) -> String {
    if node >= cluster.node_count() {
        return format!("ERR node {node} out of range\n");
    }
    let report = cluster.restart_node(node);
    format!(
        "OK replayed={} discarded={}\n",
        report.records, report.discarded_bytes
    )
}

/// Apply a `WIPE` admin command: destroy one replica's state entirely.
fn apply_wipe<B: StorageBackend<DvvMech>>(cluster: &LocalCluster<B>, node: usize) -> String {
    if node >= cluster.node_count() {
        return format!("ERR node {node} out of range\n");
    }
    cluster.wipe_node(node);
    "OK\n".to_string()
}

/// Render the membership view as a text-protocol line (one consistent
/// snapshot — epoch and members cannot straddle a concurrent bump).
fn topology_line<B: StorageBackend<DvvMech>>(cluster: &LocalCluster<B>) -> String {
    let (epoch, slots, members) = cluster.topology().snapshot();
    let members: Vec<String> = members.iter().map(|m| m.to_string()).collect();
    format!("TOPOLOGY epoch={epoch} slots={slots} members={}\n", members.join(","))
}

/// Encode the membership view as an [`protocol::OP_TOPOLOGY_REPLY`]
/// payload (one consistent snapshot).
fn topology_frame<B: StorageBackend<DvvMech>>(cluster: &LocalCluster<B>) -> Vec<u8> {
    let (epoch, slots, members) = cluster.topology().snapshot();
    let members: Vec<u64> = members.iter().map(|&m| m as u64).collect();
    protocol::encode_topology_reply(epoch, slots as u64, &members)
}

/// Apply a `HEAL` admin command: recover one node, or reset every fault
/// axis and drain parked hints.
fn apply_heal<B: StorageBackend<DvvMech>>(
    cluster: &LocalCluster<B>,
    node: Option<usize>,
) -> String {
    match node {
        Some(n) if n < cluster.node_count() => {
            cluster.fabric().recover(n);
            cluster.drain_hints();
            "OK\n".to_string()
        }
        Some(n) => format!("ERR node {n} out of range\n"),
        None => {
            cluster.fabric().heal_all();
            cluster.drain_hints();
            "OK\n".to_string()
        }
    }
}

/// Read one byte, looping on read timeouts until data arrives, the peer
/// hangs up (`None`), or the server shuts down (`None`).
fn read_byte(r: &mut impl Read, stop: &AtomicBool) -> Result<Option<u8>> {
    let mut b = [0u8; 1];
    loop {
        match r.read(&mut b) {
            Ok(0) => return Ok(None),
            Ok(_) => return Ok(Some(b[0])),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) {
                    return Ok(None);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
}

/// Fill `buf` completely, tolerating read timeouts. `Ok(false)` = clean
/// end of stream (or shutdown) before the first byte when `eof_ok`;
/// truncation mid-buffer is always an error.
fn read_full(r: &mut impl Read, buf: &mut [u8], stop: &AtomicBool, eof_ok: bool) -> Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 && eof_ok {
                    return Ok(false);
                }
                return Err(Error::Protocol("connection closed mid-frame".into()));
            }
            Ok(n) => filled += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) {
                    if filled == 0 && eof_ok {
                        return Ok(false);
                    }
                    return Err(Error::Protocol("server shutting down mid-frame".into()));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(true)
}

/// Read one v2 frame, timeout-aware. `Ok(None)` = clean disconnect.
fn read_frame_server(
    r: &mut impl Read,
    stop: &AtomicBool,
) -> Result<Option<(u8, Vec<u8>)>> {
    let mut header = [0u8; 4];
    if !read_full(r, &mut header, stop, true)? {
        return Ok(None);
    }
    let len = protocol::frame_len(header)?;
    let mut body = vec![0u8; len];
    read_full(r, &mut body, stop, false)?;
    let payload = body.split_off(1);
    Ok(Some((body[0], payload)))
}

fn handle_conn<B: StorageBackend<DvvMech>>(
    stream: TcpStream,
    cluster: &LocalCluster<B>,
    stop: &AtomicBool,
) -> Result<()> {
    // the listener is non-blocking; make sure the accepted stream is not
    // (some platforms propagate O_NONBLOCK to accepted sockets)
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true).ok();
    // bounded reads so workers notice server shutdown
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut reader = BufReader::new(stream.try_clone()?);

    // transport negotiation: sniff byte by byte, bailing to the text
    // protocol on the first byte that diverges from the magic (so a
    // short text command is answered without waiting for more input)
    let mut probe: Vec<u8> = Vec::with_capacity(protocol::MAGIC.len());
    while probe.len() < protocol::MAGIC.len() && protocol::MAGIC.starts_with(&probe) {
        match read_byte(&mut reader, stop)? {
            Some(b) => probe.push(b),
            None => return Ok(()), // hung up before the first request
        }
    }
    if probe == protocol::MAGIC {
        serve_binary(reader, stream, cluster, stop)
    } else {
        serve_text(reader, stream, cluster, stop, probe)
    }
}

/// The legacy line-based text protocol. `acc` seeds the input buffer
/// with whatever the negotiation sniff already consumed.
fn serve_text<B: StorageBackend<DvvMech>>(
    mut reader: BufReader<TcpStream>,
    mut stream: TcpStream,
    cluster: &LocalCluster<B>,
    stop: &AtomicBool,
    mut acc: Vec<u8>,
) -> Result<()> {
    let mut chunk = [0u8; 4096];
    loop {
        // drain every complete line already buffered
        while let Some(pos) = acc.iter().position(|&b| b == b'\n') {
            let line_bytes: Vec<u8> = acc.drain(..=pos).collect();
            let line = String::from_utf8_lossy(&line_bytes);
            if line.trim().is_empty() {
                continue;
            }
            let reply = match parse_request(&line) {
                Ok(Request::Get { key }) => match cluster.get(&key) {
                    Ok(ans) => format_values(&ans.values, &ans.context),
                    Err(e) => format!("ERR {e}\n"),
                },
                Ok(Request::Put { key, value, context }) => {
                    match cluster.put(&key, value, &context) {
                        Ok(()) => "OK\n".to_string(),
                        Err(e) => format!("ERR {e}\n"),
                    }
                }
                Ok(Request::Stats) => format!(
                    "STATS nodes={} shards={} metadata_bytes={} hints={} epoch={} wal_bytes={} merkle_root={}\n",
                    cluster.node_count(),
                    cluster.shard_count(),
                    cluster.metadata_bytes(),
                    cluster.pending_hints(),
                    cluster.epoch(),
                    cluster.wal_bytes(),
                    cluster.merkle_root()
                ),
                Ok(Request::Fault(cmd)) => apply_fault(cluster, cmd),
                Ok(Request::Heal { node }) => apply_heal(cluster, node),
                Ok(Request::Restart { node }) => apply_restart(cluster, node),
                Ok(Request::Wipe { node }) => apply_wipe(cluster, node),
                Ok(Request::Join) => {
                    let (id, epoch) = cluster.join_node();
                    format!("OK id={id} epoch={epoch}\n")
                }
                Ok(Request::Decommission { node }) => match cluster.decommission_node(node) {
                    Ok(epoch) => format!("OK epoch={epoch}\n"),
                    Err(e) => format!("ERR {e}\n"),
                },
                Ok(Request::Topology) => topology_line(cluster),
                Ok(Request::Quit) => {
                    stream.write_all(b"BYE\n")?;
                    return Ok(());
                }
                Err(e) => format!("ERR {e}\n"),
            };
            stream.write_all(reply.as_bytes())?;
        }
        // need more input
        match reader.read(&mut chunk) {
            Ok(0) => return Ok(()), // client hung up
            Ok(n) => acc.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
}

/// Decode a binary PUT and run it through the traced quorum path: the
/// frame's actor + ctx token make the write oracle-auditable end to end.
fn put_binary<B: StorageBackend<DvvMech>>(
    cluster: &LocalCluster<B>,
    key: &str,
    value: Vec<u8>,
    actor: u32,
    ctx_token: &[u8],
) -> Result<(u64, Option<Vec<u8>>)> {
    let (vv, observed) = if ctx_token.is_empty() {
        (Vec::new(), Vec::new())
    } else {
        CausalCtx::decode(ctx_token)?.into_parts()
    };
    cluster.put_api(key, value, &vv, Actor(actor), &observed)
}

/// Map a text-protocol admin status line (`OK\n` / `ERR …\n`) onto a
/// binary reply frame.
fn admin_status(status: String) -> (u8, Vec<u8>) {
    match status.strip_prefix("ERR ") {
        Some(msg) => (protocol::OP_ERR, msg.trim_end().as_bytes().to_vec()),
        None => (protocol::OP_OK, Vec::new()),
    }
}

/// The binary protocol v2 loop (the magic preamble is already consumed).
fn serve_binary<B: StorageBackend<DvvMech>>(
    mut reader: BufReader<TcpStream>,
    mut stream: TcpStream,
    cluster: &LocalCluster<B>,
    stop: &AtomicBool,
) -> Result<()> {
    // hello tail: requested version + newline terminator
    let Some(version) = read_byte(&mut reader, stop)? else { return Ok(()) };
    let Some(terminator) = read_byte(&mut reader, stop)? else { return Ok(()) };
    if terminator != b'\n' {
        // enforce the documented preamble: silently eating a stray byte
        // here would desynchronize every following frame
        let _ = protocol::write_frame(
            &mut stream,
            protocol::OP_ERR,
            b"malformed hello: missing newline after version byte",
        );
        return Ok(());
    }
    if version != protocol::VERSION {
        // clean version-skew rejection: one ERR frame, then close
        let msg = format!(
            "unsupported protocol version {version} (server speaks {})",
            protocol::VERSION
        );
        let _ = protocol::write_frame(&mut stream, protocol::OP_ERR, msg.as_bytes());
        return Ok(());
    }
    protocol::write_frame(&mut stream, protocol::OP_HELLO_ACK, &[protocol::VERSION])?;
    loop {
        let (opcode, payload) = match read_frame_server(&mut reader, stop) {
            Ok(Some(frame)) => frame,
            Ok(None) => return Ok(()), // clean disconnect / shutdown
            Err(e) => {
                // broken framing (zero/oversized length, truncation): the
                // byte stream can no longer be trusted — one final ERR
                // frame, then drop the connection
                let _ =
                    protocol::write_frame(&mut stream, protocol::OP_ERR, e.to_string().as_bytes());
                return Ok(());
            }
        };
        let (op, body): (u8, Vec<u8>) = match protocol::decode_bin_request(opcode, &payload) {
            Ok(BinRequest::Get { key }) => match cluster.get(&key) {
                Ok(ans) => {
                    let token = CausalCtx::new(ans.context, ans.ids).encode();
                    let payload = protocol::encode_values(&ans.values, &token);
                    // a sibling set too large for one frame must degrade
                    // to an ERR reply, not abort the connection when
                    // write_frame refuses it
                    if payload.len() >= protocol::MAX_FRAME_LEN as usize {
                        (
                            protocol::OP_ERR,
                            format!(
                                "reply of {} bytes exceeds the {}-byte frame cap",
                                payload.len(),
                                protocol::MAX_FRAME_LEN
                            )
                            .into_bytes(),
                        )
                    } else {
                        (protocol::OP_VALUES, payload)
                    }
                }
                Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
            },
            Ok(BinRequest::Put { key, value, actor, ctx_token }) => {
                match put_binary(cluster, &key, value, actor, &ctx_token) {
                    Ok((id, post)) => {
                        // empty token = no chainable context (a
                        // concurrent sibling survived; GET to merge)
                        let token = post
                            .map(|post| CausalCtx::new(post, vec![id]).encode())
                            .unwrap_or_default();
                        (protocol::OP_PUT_OK, protocol::encode_put_ok(id, &token))
                    }
                    Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
                }
            }
            Ok(BinRequest::Stats) => (
                protocol::OP_STATS_REPLY,
                protocol::encode_stats_reply(
                    cluster.node_count() as u64,
                    cluster.shard_count() as u64,
                    cluster.metadata_bytes(),
                    cluster.pending_hints() as u64,
                    cluster.epoch(),
                    cluster.wal_bytes(),
                    cluster.merkle_root(),
                ),
            ),
            Ok(BinRequest::Join) => {
                // the reply's epoch and slots come from *this* join's
                // return value, so `slots - 1` is the id assigned to
                // this request even when joins race (a fresh snapshot
                // could report another join's slots); the member list
                // is an advisory snapshot
                let (id, epoch) = cluster.join_node();
                let members: Vec<u64> =
                    cluster.members().iter().map(|&m| m as u64).collect();
                (
                    protocol::OP_TOPOLOGY_REPLY,
                    protocol::encode_topology_reply(epoch, id as u64 + 1, &members),
                )
            }
            Ok(BinRequest::Decommission { node }) => match cluster.decommission_node(node) {
                Ok(_) => (protocol::OP_TOPOLOGY_REPLY, topology_frame(cluster)),
                Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
            },
            Ok(BinRequest::Topology) => {
                (protocol::OP_TOPOLOGY_REPLY, topology_frame(cluster))
            }
            Ok(BinRequest::Admin { line }) => match parse_request(&line) {
                Ok(Request::Fault(cmd)) => admin_status(apply_fault(cluster, cmd)),
                Ok(Request::Heal { node }) => admin_status(apply_heal(cluster, node)),
                // durability faults ride the ADMIN frame in text form —
                // real storage loss at a live replica, over the wire
                Ok(Request::Restart { node }) => admin_status(apply_restart(cluster, node)),
                Ok(Request::Wipe { node }) => admin_status(apply_wipe(cluster, node)),
                // text-form elastic ops work over ADMIN too; the
                // dedicated opcodes return the richer topology frame
                Ok(Request::Join) => {
                    let _ = cluster.join_node();
                    (protocol::OP_OK, Vec::new())
                }
                Ok(Request::Decommission { node }) => {
                    match cluster.decommission_node(node) {
                        Ok(_) => (protocol::OP_OK, Vec::new()),
                        Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
                    }
                }
                Ok(Request::Topology) => {
                    (protocol::OP_TOPOLOGY_REPLY, topology_frame(cluster))
                }
                Ok(_) => (
                    protocol::OP_ERR,
                    b"ADMIN accepts FAULT/HEAL/JOIN/DECOMMISSION/TOPOLOGY/RESTART/WIPE \
                      commands only"
                        .to_vec(),
                ),
                Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
            },
            Ok(BinRequest::Quit) => {
                let _ = protocol::write_frame(&mut stream, protocol::OP_BYE, &[]);
                return Ok(());
            }
            // malformed payload inside an intact frame: report and keep
            // the connection (framing is still trustworthy)
            Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
        };
        protocol::write_frame(&mut stream, op, &body)?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::protocol::hex_encode;
    use std::io::{BufRead, BufReader, Write};

    fn client(addr: std::net::SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
        let stream = TcpStream::connect(addr).unwrap();
        (BufReader::new(stream.try_clone().unwrap()), stream)
    }

    fn send(w: &mut TcpStream, line: &str) {
        w.write_all(line.as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
    }

    fn recv(r: &mut BufReader<TcpStream>) -> String {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        line.trim_end().to_string()
    }

    #[test]
    fn end_to_end_get_put_siblings() {
        let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
        let server = Server::start("127.0.0.1:0", cluster).unwrap();
        let (mut r, mut w) = client(server.addr());

        // blind write twice -> siblings
        send(&mut w, &format!("PUT k {}", hex_encode(b"v1")));
        assert_eq!(recv(&mut r), "OK");
        send(&mut w, &format!("PUT k {}", hex_encode(b"v2")));
        assert_eq!(recv(&mut r), "OK");

        send(&mut w, "GET k");
        let header = recv(&mut r);
        assert!(header.starts_with("VALUES 2 "), "{header}");
        let ctx = header.split_whitespace().nth(2).unwrap().to_string();
        let v1 = recv(&mut r);
        let v2 = recv(&mut r);
        assert!(v1.starts_with("VALUE ") && v2.starts_with("VALUE "));

        // contextful write supersedes both siblings
        send(&mut w, &format!("PUT k {} {}", hex_encode(b"merged"), ctx));
        assert_eq!(recv(&mut r), "OK");
        send(&mut w, "GET k");
        let header = recv(&mut r);
        assert!(header.starts_with("VALUES 1 "), "{header}");
        assert_eq!(recv(&mut r), format!("VALUE {}", hex_encode(b"merged")));

        send(&mut w, "STATS");
        assert!(recv(&mut r).starts_with("STATS nodes=3"));
        send(&mut w, "QUIT");
        assert_eq!(recv(&mut r), "BYE");
        server.shutdown();
    }

    #[test]
    fn protocol_errors_are_reported_not_fatal() {
        let cluster = Arc::new(LocalCluster::new(2, 2, 1, 1).unwrap());
        let server = Server::start("127.0.0.1:0", cluster).unwrap();
        let (mut r, mut w) = client(server.addr());
        send(&mut w, "BOGUS");
        assert!(recv(&mut r).starts_with("ERR "));
        // connection still usable
        send(&mut w, &format!("PUT a {}", hex_encode(b"x")));
        assert_eq!(recv(&mut r), "OK");
        server.shutdown();
    }

    #[test]
    fn text_elastic_ops_bump_epochs_and_sessions_survive() {
        let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
        let server = Server::start("127.0.0.1:0", cluster).unwrap();
        let (mut r, mut w) = client(server.addr());
        send(&mut w, &format!("PUT k {}", hex_encode(b"v")));
        assert_eq!(recv(&mut r), "OK");
        send(&mut w, "STATS");
        let stats = recv(&mut r);
        assert!(stats.contains(" epoch=1"), "{stats}");

        send(&mut w, "JOIN");
        assert_eq!(recv(&mut r), "OK id=3 epoch=2");
        send(&mut w, "TOPOLOGY");
        assert_eq!(recv(&mut r), "TOPOLOGY epoch=2 slots=4 members=0,1,2,3");
        send(&mut w, "DECOMMISSION 0");
        assert_eq!(recv(&mut r), "OK epoch=3");
        send(&mut w, "TOPOLOGY");
        assert_eq!(recv(&mut r), "TOPOLOGY epoch=3 slots=4 members=1,2,3");

        // the same session keeps serving across both epoch bumps
        send(&mut w, "GET k");
        let header = recv(&mut r);
        assert!(header.starts_with("VALUES 1 "), "{header}");
        let _ = recv(&mut r);

        send(&mut w, "DECOMMISSION 9");
        assert!(recv(&mut r).starts_with("ERR "), "unknown node refused");
        server.shutdown();
    }

    #[test]
    fn restart_and_wipe_admin_ops_over_text() {
        let dir = crate::testkit::temp_dir("tcp-restart");
        let cluster = Arc::new(
            LocalCluster::with_data_dir(3, 3, 2, 2, 4, &dir, crate::store::WalOptions::default())
                .unwrap(),
        );
        let server = Server::start("127.0.0.1:0", Arc::clone(&cluster)).unwrap();
        let (mut r, mut w) = client(server.addr());
        for i in 0..10 {
            send(&mut w, &format!("PUT k{i} {}", hex_encode(b"v")));
            assert_eq!(recv(&mut r), "OK");
        }
        send(&mut w, "STATS");
        let stats = recv(&mut r);
        assert!(stats.contains(" wal_bytes="), "{stats}");
        let wal_bytes: u64 = stats
            .rsplit("wal_bytes=")
            .next()
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(wal_bytes > 0, "{stats}");
        // converged 3-way replication (n=3 over 3 nodes): every member
        // holds every key, so the cluster root is the members' common
        // store root — observable (and nonzero) over live TCP
        let merkle_root: u64 = stats
            .rsplit("merkle_root=")
            .next()
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert_ne!(merkle_root, 0, "{stats}");

        // fsync default is every-64 and nothing was explicitly synced,
        // so the crash-restart loses node 1's whole unsynced tail; the
        // wipe empties node 2 outright — only node 0 still holds data
        send(&mut w, "RESTART 1");
        let reply = recv(&mut r);
        assert!(reply.starts_with("OK replayed="), "{reply}");
        send(&mut w, "WIPE 2");
        assert_eq!(recv(&mut r), "OK");
        send(&mut w, "RESTART 99");
        assert!(recv(&mut r).starts_with("ERR "), "out-of-range refused");

        // rejoin: anti-entropy re-delivers from the surviving replica
        // (a GET's answer is fixed at the first R replies in preference
        // order, so without this a key homed on the two emptied nodes
        // would legitimately answer VALUES 0)
        let mut rounds = 0;
        while cluster.anti_entropy_round() > 0 {
            rounds += 1;
            assert!(rounds < 32, "anti-entropy failed to quiesce");
        }
        for i in 0..10 {
            send(&mut w, &format!("GET k{i}"));
            let header = recv(&mut r);
            assert!(header.starts_with("VALUES 1 "), "{header}");
            let _ = recv(&mut r);
        }
        server.shutdown();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn concurrent_clients() {
        let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
        let server = Server::start("127.0.0.1:0", cluster).unwrap();
        let addr = server.addr();
        let mut handles = Vec::new();
        for t in 0..4 {
            handles.push(std::thread::spawn(move || {
                let (mut r, mut w) = client(addr);
                for i in 0..20 {
                    send(&mut w, &format!("PUT t{t}k{i} {}", hex_encode(b"data")));
                    assert_eq!(recv(&mut r), "OK");
                }
                for i in 0..20 {
                    send(&mut w, &format!("GET t{t}k{i}"));
                    let header = recv(&mut r);
                    assert!(header.starts_with("VALUES 1 "), "{header}");
                    let _ = recv(&mut r);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        server.shutdown();
    }
}
