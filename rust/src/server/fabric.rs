//! Chaos fabric: the runtime-mutable switchboard every inter-replica
//! interaction of the threaded [`LocalCluster`](super::LocalCluster) is
//! routed through.
//!
//! The discrete-event simulator injects faults by construction — every
//! message hop consults [`crate::net::NetModel`]. The threaded cluster
//! calls peer stores directly, so without a choke point its PUT fan-out,
//! GET sub-reads, read repair, and anti-entropy exchanges could never be
//! exercised under partition, crash, or loss. The `Fabric` is that choke
//! point: before touching a peer's store, the caller asks
//! [`deliver`](Fabric::deliver) whether the message would have arrived,
//! and the fabric answers from its current fault state — crashed nodes,
//! symmetric partitions, probabilistic drops, and bounded injected
//! delays — mirroring the `NetModel` semantics (loopback is exempt from
//! everything; a node always reaches its own store).
//!
//! Fault state mutates at runtime from three sources:
//!
//! * direct calls ([`crash`](Fabric::crash), \
//!   [`partition_groups`](Fabric::partition_groups), …) — what the
//!   `FAULT`/`HEAL` admin commands of the TCP protocol invoke;
//! * a [`FaultPlan`] stepped through [`advance`](Fabric::advance) — the
//!   *same* schedule type the simulator consumes, so one scenario drives
//!   both worlds (`rust/tests/fabric_chaos.rs`);
//! * [`heal_all`](Fabric::heal_all), the big red reset switch.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

use crate::cluster::NodeId;
use crate::net::BlockedPairs;
use crate::sim::failure::{Fault, FaultPlan};
use crate::testkit::Rng;

/// Cap on the injected per-message delay so a hostile schedule cannot
/// wedge connection threads (the "bounded delays" guarantee).
pub const MAX_INJECTED_DELAY_US: u64 = 50_000;

/// Runtime-mutable fault state shared by every cluster thread. All
/// methods take `&self`; interior synchronization only.
#[derive(Debug)]
pub struct Fabric {
    /// Per-node liveness; crashed nodes drop all traffic in and out.
    /// Behind a `RwLock` so the fabric can grow when a node joins at
    /// runtime ([`grow_to`](Fabric::grow_to)); the flags themselves stay
    /// atomic, so routing only ever takes the read lock.
    up: RwLock<Vec<AtomicBool>>,
    /// Active partitions (the same [`BlockedPairs`] semantics the
    /// simulator's `NetModel` uses).
    blocked: RwLock<BlockedPairs>,
    /// Per-node cumulative physical-clock offset (µs, signed) — the
    /// [`Fault::ClockSkew`] axis. Routing never consults it; the
    /// cluster's HLC stamping reads it to derive each node's injected
    /// physical time ([`clock_skew_us`](Fabric::clock_skew_us)).
    skew_us: RwLock<Vec<i64>>,
    /// Message-drop probability in parts-per-million.
    drop_ppm: AtomicU32,
    /// Fixed extra one-way delay injected per message (µs, capped).
    extra_delay_us: AtomicU64,
    /// Deterministic drop-roll stream (given single-threaded driving).
    rng: Mutex<Rng>,
    /// Messages allowed through.
    delivered: AtomicU64,
    /// Messages refused (crash, partition, or drop roll).
    dropped: AtomicU64,
    /// Virtual time up to (and including) which a [`FaultPlan`] has been
    /// applied; `None` until the first [`advance`](Fabric::advance), so
    /// faults scheduled at `t = 0` are not skipped.
    cursor_us: Mutex<Option<u64>>,
}

impl Fabric {
    /// All-clear fabric for `nodes` replicas.
    pub fn new(nodes: usize, seed: u64) -> Fabric {
        Fabric {
            up: RwLock::new((0..nodes).map(|_| AtomicBool::new(true)).collect()),
            blocked: RwLock::new(BlockedPairs::new()),
            skew_us: RwLock::new(vec![0; nodes]),
            drop_ppm: AtomicU32::new(0),
            extra_delay_us: AtomicU64::new(0),
            rng: Mutex::new(Rng::new(seed)),
            delivered: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            cursor_us: Mutex::new(None),
        }
    }

    /// Number of nodes the fabric routes for.
    pub fn node_count(&self) -> usize {
        self.up.read().unwrap().len()
    }

    /// Grow the fabric to route for at least `nodes` replicas (elastic
    /// topology: joined nodes start up with clean links). Shrinking
    /// never happens — decommissioned nodes keep their slot so parked
    /// hints and in-flight handoff can still route.
    pub fn grow_to(&self, nodes: usize) {
        let mut up = self.up.write().unwrap();
        while up.len() < nodes {
            up.push(AtomicBool::new(true));
        }
        let mut skew = self.skew_us.write().unwrap();
        if skew.len() < nodes {
            skew.resize(nodes, 0);
        }
    }

    /// Reset the drop-roll RNG (reproducible chaos runs).
    pub fn reseed(&self, seed: u64) {
        *self.rng.lock().unwrap() = Rng::new(seed);
    }

    // -----------------------------------------------------------------
    // fault state mutation
    // -----------------------------------------------------------------

    /// Crash a node: every message to or from it is refused. Unknown
    /// ids are ignored (a schedule can race a join).
    pub fn crash(&self, node: NodeId) {
        if let Some(flag) = self.up.read().unwrap().get(node) {
            flag.store(false, Ordering::Relaxed);
        }
    }

    /// Recover a crashed node.
    pub fn recover(&self, node: NodeId) {
        if let Some(flag) = self.up.read().unwrap().get(node) {
            flag.store(true, Ordering::Relaxed);
        }
    }

    /// Is the node currently up? Unknown ids are down by definition.
    pub fn is_up(&self, node: NodeId) -> bool {
        self.up
            .read()
            .unwrap()
            .get(node)
            .map(|flag| flag.load(Ordering::Relaxed))
            .unwrap_or(false)
    }

    /// Install a symmetric partition between every `left`/`right` pair.
    pub fn partition_groups(&self, left: &[NodeId], right: &[NodeId]) {
        self.blocked.write().unwrap().block_groups(left, right);
    }

    /// Remove every partition (crashed nodes stay crashed).
    pub fn heal_partitions(&self) {
        self.blocked.write().unwrap().clear();
    }

    /// Is the pair currently partitioned?
    pub fn is_partitioned(&self, a: NodeId, b: NodeId) -> bool {
        self.blocked.read().unwrap().contains(a, b)
    }

    /// Set the probabilistic message-drop rate.
    pub fn set_drop_prob(&self, prob: f64) {
        self.drop_ppm.store(crate::sim::failure::drop_ppm(prob), Ordering::Relaxed);
    }

    /// Current drop probability.
    pub fn drop_prob(&self) -> f64 {
        self.drop_ppm.load(Ordering::Relaxed) as f64 / 1_000_000.0
    }

    /// Set the injected per-message delay (µs; capped at
    /// [`MAX_INJECTED_DELAY_US`] at delivery time).
    pub fn set_extra_delay_us(&self, us: u64) {
        self.extra_delay_us.store(us, Ordering::Relaxed);
    }

    /// Current injected per-message delay (µs).
    pub fn extra_delay_us(&self) -> u64 {
        self.extra_delay_us.load(Ordering::Relaxed)
    }

    /// Degrade the network: drops plus delay. `(0.0, 0)` restores clean
    /// links (the [`Fault::Degrade`] semantics).
    pub fn degrade(&self, drop_prob: f64, extra_delay_us: u64) {
        self.set_drop_prob(drop_prob);
        self.set_extra_delay_us(extra_delay_us);
    }

    /// Step one node's physical clock by a signed offset (µs),
    /// **cumulative** with previous steps — the [`Fault::ClockSkew`]
    /// semantics. Unknown ids are ignored (a schedule can race a join).
    pub fn add_clock_skew(&self, node: NodeId, delta_us: i64) {
        if let Some(s) = self.skew_us.write().unwrap().get_mut(node) {
            *s += delta_us;
        }
    }

    /// The node's cumulative physical-clock offset (µs; 0 for unknown
    /// ids). The cluster derives a node's injected physical time as
    /// `plan cursor + skew`, clamped at zero.
    pub fn clock_skew_us(&self, node: NodeId) -> i64 {
        self.skew_us.read().unwrap().get(node).copied().unwrap_or(0)
    }

    /// Full reset: recover every node, heal every partition, restore
    /// clean links. (The plan cursor is *not* rewound; a drained plan
    /// stays drained.)
    pub fn heal_all(&self) {
        for node in self.up.read().unwrap().iter() {
            node.store(true, Ordering::Relaxed);
        }
        self.heal_partitions();
        self.degrade(0.0, 0);
        self.skew_us.write().unwrap().fill(0);
    }

    // -----------------------------------------------------------------
    // routing
    // -----------------------------------------------------------------

    /// Is the link even open — both endpoints up and not partitioned?
    /// (No drop roll, no delay; loopback only needs the node up.)
    pub fn link_open(&self, from: NodeId, to: NodeId) -> bool {
        if !self.is_up(from) || !self.is_up(to) {
            return false;
        }
        from == to || !self.is_partitioned(from, to)
    }

    /// Would a message from `from` to `to` arrive? Applies the full
    /// fault model: crash, partition, drop roll, then the injected delay
    /// (a real, bounded `sleep` — concurrency under degraded links is
    /// exactly what the chaos tests exercise). Loopback skips partition,
    /// drop, and delay, mirroring [`crate::net::NetModel::delay`].
    pub fn deliver(&self, from: NodeId, to: NodeId) -> bool {
        if from == to {
            let up = self.is_up(from);
            self.count(up);
            return up;
        }
        if !self.link_open(from, to) {
            self.count(false);
            return false;
        }
        let ppm = self.drop_ppm.load(Ordering::Relaxed);
        if ppm > 0 {
            let dropped = self.rng.lock().unwrap().below(1_000_000) < u64::from(ppm);
            if dropped {
                self.count(false);
                return false;
            }
        }
        let delay = self.extra_delay_us.load(Ordering::Relaxed).min(MAX_INJECTED_DELAY_US);
        if delay > 0 {
            std::thread::sleep(std::time::Duration::from_micros(delay));
        }
        self.count(true);
        true
    }

    fn count(&self, delivered: bool) {
        if delivered {
            self.delivered.fetch_add(1, Ordering::Relaxed);
        } else {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Messages allowed through so far.
    pub fn delivered(&self) -> u64 {
        self.delivered.load(Ordering::Relaxed)
    }

    /// Messages refused so far (crash, partition, or drop roll).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    // -----------------------------------------------------------------
    // schedule driving
    // -----------------------------------------------------------------

    /// Apply one fault *now*, ignoring its timestamp.
    ///
    /// Membership faults are only partially a fabric concern: a
    /// [`Fault::Join`] grows the routing table (the new node's links
    /// start clean), while a [`Fault::Decommission`] is a **no-op** here
    /// — the node must stay routable so its key handoff and parked hints
    /// can drain. Spinning up / retiring the actual replica is the
    /// cluster's job; step churn-bearing plans through
    /// [`LocalCluster::advance_plan`](super::LocalCluster::advance_plan),
    /// which intercepts both kinds before delegating the rest here.
    pub fn apply_fault(&self, fault: &Fault) {
        match fault {
            Fault::Crash { node, .. } => self.crash(*node),
            Fault::Recover { node, .. } => self.recover(*node),
            Fault::Partition { left, right, .. } => self.partition_groups(left, right),
            Fault::Heal { .. } => self.heal_partitions(),
            Fault::Degrade { drop_ppm, extra_delay_us, .. } => {
                self.drop_ppm.store(*drop_ppm, Ordering::Relaxed);
                self.set_extra_delay_us(*extra_delay_us);
            }
            Fault::Join { .. } => self.grow_to(self.node_count() + 1),
            Fault::Decommission { .. } => {}
            Fault::ClockSkew { node, delta_us, .. } => {
                self.add_clock_skew(*node, *delta_us)
            }
            // state loss is a *storage* fault, not a link fault: the
            // cluster applies it to the node's backend in `advance_plan`;
            // links and liveness are untouched (pair with a crash window
            // to model downtime)
            Fault::Restart { .. } | Fault::Wipe { .. } => {}
        }
    }

    /// Advance the plan's virtual clock to `to_us`: apply, in timestamp
    /// order, every not-yet-applied fault with `at <= to_us` (the first
    /// call covers `t = 0` faults, matching the simulator which fires
    /// them at time zero). Stepping a schedule this way while worker
    /// threads run is how a [`FaultPlan`] validated in the simulator
    /// replays against the threaded cluster.
    pub fn advance(&self, plan: &FaultPlan, to_us: u64) {
        self.advance_each(plan, to_us, |fault| self.apply_fault(fault));
    }

    /// The cursor walk behind [`advance`](Fabric::advance), with the
    /// application step abstracted out: the cluster's
    /// [`advance_plan`](super::LocalCluster::advance_plan) passes a
    /// closure that routes membership faults to `join_node` /
    /// `decommission_node` and everything else back to
    /// [`apply_fault`](Fabric::apply_fault). The cursor mutex is held
    /// across the walk, so one thread applies a given fault exactly once.
    pub fn advance_each(&self, plan: &FaultPlan, to_us: u64, mut apply: impl FnMut(&Fault)) {
        let mut cursor = self.cursor_us.lock().unwrap();
        let from = match *cursor {
            Some(c) if to_us <= c => return,
            Some(c) => c.saturating_add(1),
            None => 0,
        };
        let mut due: Vec<&Fault> = plan
            .faults
            .iter()
            .filter(|f| f.at() >= from && f.at() <= to_us)
            .collect();
        due.sort_by_key(|f| f.at());
        for fault in due {
            apply(fault);
        }
        *cursor = Some(to_us);
    }

    /// Virtual time the plan cursor has reached (0 before any advance).
    pub fn cursor_us(&self) -> u64 {
        self.cursor_us.lock().unwrap().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_clear_delivers_everything() {
        let f = Fabric::new(3, 1);
        for a in 0..3 {
            for b in 0..3 {
                assert!(f.deliver(a, b));
            }
        }
        assert_eq!(f.delivered(), 9);
        assert_eq!(f.dropped(), 0);
    }

    #[test]
    fn crash_blocks_both_directions_until_recover() {
        let f = Fabric::new(3, 1);
        f.crash(1);
        assert!(!f.is_up(1));
        assert!(!f.deliver(0, 1));
        assert!(!f.deliver(1, 0));
        assert!(!f.deliver(1, 1), "a crashed node cannot even reach itself");
        assert!(f.deliver(0, 2));
        f.recover(1);
        assert!(f.deliver(0, 1));
    }

    #[test]
    fn partitions_are_symmetric_and_heal() {
        let f = Fabric::new(4, 1);
        f.partition_groups(&[0, 1], &[2, 3]);
        assert!(f.is_partitioned(0, 2) && f.is_partitioned(2, 0));
        assert!(!f.deliver(1, 3) && !f.deliver(3, 1));
        assert!(f.deliver(0, 1), "same side unaffected");
        f.heal_partitions();
        assert!(f.deliver(1, 3));
    }

    #[test]
    fn loopback_survives_partition_and_drops() {
        let f = Fabric::new(2, 1);
        f.partition_groups(&[0], &[0, 1]); // nonsense self-pair included
        f.set_drop_prob(1.0);
        for _ in 0..50 {
            assert!(f.deliver(0, 0), "loopback is exempt from faults");
        }
        assert!(!f.deliver(0, 1));
    }

    #[test]
    fn drop_prob_is_respected() {
        let f = Fabric::new(2, 7);
        f.set_drop_prob(0.5);
        let through = (0..2000).filter(|_| f.deliver(0, 1)).count();
        assert!((800..1200).contains(&through), "through={through}");
        f.set_drop_prob(0.0);
        assert!(f.deliver(0, 1));
    }

    #[test]
    fn heal_all_resets_every_fault_axis() {
        let f = Fabric::new(3, 1);
        f.crash(0);
        f.partition_groups(&[1], &[2]);
        f.degrade(1.0, 99);
        f.heal_all();
        assert!(f.is_up(0));
        assert!(!f.is_partitioned(1, 2));
        assert_eq!(f.drop_prob(), 0.0);
        assert_eq!(f.extra_delay_us(), 0);
        for a in 0..3 {
            for b in 0..3 {
                assert!(f.deliver(a, b));
            }
        }
    }

    #[test]
    fn advance_applies_faults_in_order_once() {
        let plan = FaultPlan::new()
            .crash_window(0, 100, 200)
            .partition_window(vec![0], vec![1], 150, 300);
        let f = Fabric::new(2, 1);
        f.advance(&plan, 50);
        assert!(f.is_up(0));
        f.advance(&plan, 120);
        assert!(!f.is_up(0), "crash at 100 applied");
        f.advance(&plan, 120); // idempotent: cursor does not rewind
        f.advance(&plan, 250);
        assert!(f.is_up(0), "recover at 200 applied");
        assert!(f.is_partitioned(0, 1), "partition at 150 applied");
        f.advance(&plan, 1000);
        assert!(!f.is_partitioned(0, 1), "heal at 300 applied");
        assert_eq!(f.cursor_us(), 1000);
    }

    #[test]
    fn advance_applies_time_zero_faults() {
        // regression: the first advance must cover t = 0 faults exactly
        // like the simulator fires them at time zero
        let plan = FaultPlan::new().crash_window(0, 0, 100);
        let f = Fabric::new(1, 1);
        assert_eq!(f.cursor_us(), 0);
        f.advance(&plan, 50);
        assert!(!f.is_up(0), "crash at t=0 applied on the first advance");
        f.advance(&plan, 100);
        assert!(f.is_up(0));
    }

    #[test]
    fn same_timestamp_faults_apply_in_plan_order() {
        // crash and recover at the same instant: plan order wins, so the
        // node ends up recovered
        let plan = FaultPlan {
            faults: vec![
                Fault::Crash { at: 10, node: 0 },
                Fault::Recover { at: 10, node: 0 },
            ],
        };
        let f = Fabric::new(1, 1);
        f.advance(&plan, 10);
        assert!(f.is_up(0));
    }

    #[test]
    fn grow_to_adds_clean_links_and_never_shrinks() {
        let f = Fabric::new(2, 1);
        f.crash(1);
        f.grow_to(4);
        assert_eq!(f.node_count(), 4);
        assert!(f.is_up(2) && f.is_up(3), "joined nodes start up");
        assert!(!f.is_up(1), "existing fault state survives growth");
        assert!(f.deliver(0, 3));
        f.grow_to(3);
        assert_eq!(f.node_count(), 4, "grow_to never shrinks");
    }

    #[test]
    fn unknown_nodes_are_down_and_fault_calls_ignore_them() {
        let f = Fabric::new(2, 1);
        assert!(!f.is_up(9));
        f.crash(9); // out of range: ignored, not a panic
        f.recover(9);
        assert!(f.deliver(0, 1), "known links unaffected");
    }

    #[test]
    fn join_fault_grows_and_decommission_fault_keeps_routing() {
        let plan = FaultPlan::new().join_at(100).decommission_at(200, 0);
        let f = Fabric::new(2, 1);
        f.advance(&plan, 150);
        assert_eq!(f.node_count(), 3, "Join fault grew the fabric");
        f.advance(&plan, 250);
        assert!(f.is_up(0), "decommissioned node stays routable for handoff");
        assert!(f.deliver(0, 1));
    }

    #[test]
    fn advance_each_hands_faults_to_the_caller_once() {
        let plan = FaultPlan::new().crash_window(0, 100, 200).join_at(150);
        let f = Fabric::new(2, 1);
        let mut seen = Vec::new();
        f.advance_each(&plan, 180, |fault| seen.push(fault.at()));
        assert_eq!(seen, vec![100, 150]);
        seen.clear();
        f.advance_each(&plan, 180, |fault| seen.push(fault.at()));
        assert!(seen.is_empty(), "cursor does not rewind");
        f.advance_each(&plan, 500, |fault| seen.push(fault.at()));
        assert_eq!(seen, vec![200]);
        // the closure decided what to do: the fabric itself is untouched
        assert!(f.is_up(0));
        assert_eq!(f.node_count(), 2);
    }

    #[test]
    fn clock_skew_accumulates_heals_and_survives_growth() {
        let f = Fabric::new(2, 1);
        assert_eq!(f.clock_skew_us(0), 0);
        f.add_clock_skew(0, -300);
        f.add_clock_skew(0, 100);
        assert_eq!(f.clock_skew_us(0), -200, "skew is cumulative");
        assert_eq!(f.clock_skew_us(1), 0, "other nodes untouched");
        f.add_clock_skew(9, 50); // unknown id: ignored
        assert_eq!(f.clock_skew_us(9), 0);
        f.grow_to(4);
        assert_eq!(f.clock_skew_us(0), -200, "growth keeps existing skew");
        assert_eq!(f.clock_skew_us(3), 0, "joined nodes start unskewed");
        let plan = FaultPlan::new().clock_skew_at(100, 1, -9_000);
        f.advance(&plan, 150);
        assert_eq!(f.clock_skew_us(1), -9_000, "ClockSkew fault applied");
        f.heal_all();
        assert_eq!(f.clock_skew_us(0), 0);
        assert_eq!(f.clock_skew_us(1), 0, "heal_all resets the skew axis");
    }

    #[test]
    fn degrade_fault_sets_and_restores_link_quality() {
        let plan = FaultPlan::new().degrade_window(0.25, 400, 100, 200);
        let f = Fabric::new(2, 1);
        f.advance(&plan, 150);
        assert!((f.drop_prob() - 0.25).abs() < 1e-9);
        assert_eq!(f.extra_delay_us(), 400);
        f.advance(&plan, 250);
        assert_eq!(f.drop_prob(), 0.0);
        assert_eq!(f.extra_delay_us(), 0);
    }
}
