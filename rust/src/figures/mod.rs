//! Executable replays of the paper's figures.
//!
//! Each `figN()` replays the canonical run — clients C1, C2, C3 against
//! replica nodes Ra, Rb — under the mechanism the figure illustrates,
//! *asserting* every intermediate and final state the paper prints, and
//! returning a step-by-step trace for the CLI (`dvv-store figures --fig N`).
//!
//! | figure | mechanism                       | outcome asserted              |
//! |--------|---------------------------------|-------------------------------|
//! | 1      | causal histories                | exact event sets              |
//! | 2      | synchronized real-time LWW      | v, w, x lost; only y survives |
//! | 3      | per-server version vectors      | w falsely dominates v         |
//! | 4      | per-client VVs, stateless       | y falsely dominates v         |
//! | 7      | dotted version vectors          | exact DVVs incl. anti-entropy |
//!
//! Figures 5 and 6 are the get/put message-flow diagrams; they are
//! exercised (with assertions on the §4.1 step structure) by the
//! simulator's quorum tests rather than replayed here.

use std::fmt::Write as _;

use crate::clocks::causal_history::hist;
use crate::clocks::dvv::dvv;
use crate::clocks::vv::vv;
use crate::clocks::{Actor, ClockOrd, LogicalClock};
use crate::kernel::mechs::{ClientVvMech, DvvMech, HistoryMech, LwwMech, ServerVvMech};
use crate::kernel::{Mechanism, Val, WriteMeta};

/// A replayed figure: narrative steps plus final per-replica states.
#[derive(Debug, Clone)]
pub struct FigureReport {
    /// e.g. "Figure 7".
    pub title: String,
    /// Human-readable step lines ("C1 PUT v at Rb -> (b,0,1)").
    pub steps: Vec<String>,
    /// Final committed state per replica, rendered.
    pub finals: Vec<String>,
}

impl FigureReport {
    fn new(title: &str) -> FigureReport {
        FigureReport { title: title.to_string(), steps: Vec::new(), finals: Vec::new() }
    }

    fn step(&mut self, s: String) {
        self.steps.push(s);
    }

    /// Render the whole report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        for (i, s) in self.steps.iter().enumerate() {
            let _ = writeln!(out, "  {:>2}. {s}", i + 1);
        }
        let _ = writeln!(out, "  final:");
        for f in &self.finals {
            let _ = writeln!(out, "    {f}");
        }
        out
    }
}

fn ra() -> Actor {
    Actor::server(0)
}
fn rb() -> Actor {
    Actor::server(1)
}
fn c1() -> Actor {
    Actor::client(0)
}
fn c2() -> Actor {
    Actor::client(1)
}
fn c3() -> Actor {
    Actor::client(2)
}

// Values: v=1, x=2, w=3, y=4, z=5 (ids fixed so traces are stable).
const V: Val = Val { id: 1, len: 0 };
const X: Val = Val { id: 2, len: 0 };
const W: Val = Val { id: 3, len: 0 };
const Y: Val = Val { id: 4, len: 0 };
const Z: Val = Val { id: 5, len: 0 };

fn name(v: Val) -> &'static str {
    match v.id {
        1 => "v",
        2 => "x",
        3 => "w",
        4 => "y",
        5 => "z",
        _ => "?",
    }
}

/// Figure 1: the run under causal histories (ground truth).
pub fn fig1() -> FigureReport {
    let m = HistoryMech;
    let mut r = FigureReport::new(
        "Figure 1 — causal histories: three clients, two replicas",
    );
    let mut sa: <HistoryMech as Mechanism>::State = Vec::new();
    let mut sb: <HistoryMech as Mechanism>::State = Vec::new();
    let (_, ctx0) = m.read(&sa);

    m.write(&mut sb, &ctx0, V, rb(), &WriteMeta::basic(c1()));
    r.step(format!("C1 PUT v at Rb with ctx {{}} -> {}", sb[0].0));
    assert_eq!(sb[0].0, hist(&[(rb(), 1)]));

    m.write(&mut sa, &ctx0, X, ra(), &WriteMeta::basic(c3()));
    r.step(format!("C3 PUT x at Ra with ctx {{}} -> {}", sa[0].0));
    assert_eq!(sa[0].0, hist(&[(ra(), 1)]));

    m.write(&mut sb, &ctx0, W, rb(), &WriteMeta::basic(c2()));
    r.step(format!(
        "C2 PUT w at Rb with ctx {{}} -> {} (concurrent with v: kept)",
        sb[1].0
    ));
    assert_eq!(sb.len(), 2);
    assert_eq!(sb[1].0, hist(&[(rb(), 2)]));

    let (vals, ctx_a) = m.read(&sa);
    assert_eq!(vals, vec![X]);
    m.write(&mut sa, &ctx_a, Y, ra(), &WriteMeta::basic(c1()));
    r.step(format!("C1 GET at Ra (x, ctx {ctx_a}), PUT y -> {}", sa[0].0));
    assert_eq!(sa.len(), 1, "y supersedes x");
    assert_eq!(sa[0].0, hist(&[(ra(), 1), (ra(), 2)]));

    // final relations: y || v, y || w, v || w
    for (h, _) in &sb {
        assert_eq!(sa[0].0.compare(h), ClockOrd::Concurrent);
    }
    assert_eq!(sb[0].0.compare(&sb[1].0), ClockOrd::Concurrent);
    r.finals.push(format!(
        "Ra: {}",
        sa.iter().map(|(h, v)| format!("{}:{}", name(*v), h)).collect::<Vec<_>>().join(" ")
    ));
    r.finals.push(format!(
        "Rb: {}",
        sb.iter().map(|(h, v)| format!("{}:{}", name(*v), h)).collect::<Vec<_>>().join(" ")
    ));
    r
}

/// Figure 2: perfectly synchronized real-time clocks (LWW).
pub fn fig2() -> FigureReport {
    let m = LwwMech;
    let mut r = FigureReport::new(
        "Figure 2 — synchronized client clocks, last-writer-wins",
    );
    let mut sa: <LwwMech as Mechanism>::State = None;
    let mut sb: <LwwMech as Mechanism>::State = None;
    let meta = |client: Actor, t: u64| WriteMeta { client, physical_us: t, client_seq: None };

    m.write(&mut sb, &(), V, rb(), &meta(c1(), 10));
    r.step("C1 PUT v at Rb @t=10 -> stored t10".into());
    m.write(&mut sa, &(), X, ra(), &meta(c3(), 20));
    r.step("C3 PUT x at Ra @t=20 -> stored t20".into());
    m.write(&mut sb, &(), W, rb(), &meta(c2(), 30));
    r.step("C2 PUT w at Rb @t=30 -> v overwritten (t10 < t30)".into());
    assert_eq!(m.values(&sb), vec![W]);
    m.write(&mut sa, &(), Y, ra(), &meta(c1(), 40));
    r.step("C1 PUT y at Ra @t=40 -> x overwritten".into());
    assert_eq!(m.values(&sa), vec![Y]);

    // convergence: y (t=40) wins everywhere; v, w, x all lost although
    // v/w/y were mutually concurrent
    m.merge(&mut sb, &sa);
    m.merge(&mut sa, &sb);
    assert_eq!(m.values(&sa), vec![Y]);
    assert_eq!(m.values(&sb), vec![Y]);
    r.step("anti-entropy: both replicas converge to y (highest stamp)".into());
    r.finals.push("Ra: y@t40   (v, w, x lost — concurrency linearized)".into());
    r.finals.push("Rb: y@t40".into());
    r
}

/// Figure 3: version vectors with per-server entries.
pub fn fig3() -> FigureReport {
    let m = ServerVvMech;
    let mut r = FigureReport::new(
        "Figure 3 — per-server version vectors (Dynamo-style)",
    );
    let mut sa: <ServerVvMech as Mechanism>::State = Vec::new();
    let mut sb: <ServerVvMech as Mechanism>::State = Vec::new();
    let empty = Default::default();

    m.write(&mut sb, &empty, V, rb(), &WriteMeta::basic(c1()));
    r.step(format!("C1 PUT v at Rb -> {}", sb[0].0));
    assert_eq!(sb[0].0, vv(&[(rb(), 1)]));

    m.write(&mut sa, &empty, X, ra(), &WriteMeta::basic(c3()));
    r.step(format!("C3 PUT x at Ra -> {}", sa[0].0));

    m.write(&mut sb, &empty, W, rb(), &WriteMeta::basic(c2()));
    r.step(format!(
        "C2 PUT w at Rb (blind) -> {} — v FALSELY dominated and dropped",
        sb[0].0
    ));
    assert_eq!(sb.len(), 1, "the §3.2 anomaly: same-server concurrency lost");
    assert_eq!(sb[0].0, vv(&[(rb(), 2)]));
    assert_eq!(sb[0].1, W);

    let (_, ctx) = m.read(&sa);
    m.write(&mut sa, &ctx, Y, ra(), &WriteMeta::basic(c1()));
    r.step(format!("C1 GET at Ra, PUT y -> {}", sa[0].0));
    assert_eq!(sa[0].0, vv(&[(ra(), 2)]));

    // cross-server concurrency is detected: y || w
    assert_eq!(sa[0].0.compare(&sb[0].0), ClockOrd::Concurrent);
    r.step("cross-server: {(a,2)} || {(b,2)} correctly concurrent".into());
    r.finals.push(format!("Ra: y:{}", sa[0].0));
    r.finals.push(format!("Rb: w:{}  (v lost to same-server linearization)", sb[0].0));
    r
}

/// Figure 4: version vectors with per-client entries, stateless clients.
pub fn fig4() -> FigureReport {
    let m = ClientVvMech;
    let mut r = FigureReport::new(
        "Figure 4 — per-client version vectors, stateless clients",
    );
    let mut sa: <ClientVvMech as Mechanism>::State = Vec::new();
    let mut sb: <ClientVvMech as Mechanism>::State = Vec::new();
    let empty = Default::default();
    let stateless = |client: Actor| WriteMeta { client, physical_us: 0, client_seq: None };

    m.write(&mut sb, &empty, V, rb(), &stateless(c1()));
    r.step(format!("C1 PUT v at Rb -> {} (inferred (C1,1))", sb[0].0));
    assert_eq!(sb[0].0, vv(&[(c1(), 1)]));

    m.write(&mut sa, &empty, X, ra(), &stateless(c3()));
    r.step(format!("C3 PUT x at Ra -> {}", sa[0].0));

    m.write(&mut sb, &empty, W, rb(), &stateless(c2()));
    r.step(format!("C2 PUT w at Rb -> {} (sibling kept — per-client entries)", sb[1].0));
    assert_eq!(sb.len(), 2, "per-client entries keep same-server concurrency");

    let (_, ctx) = m.read(&sa);
    m.write(&mut sa, &ctx, Y, ra(), &stateless(c1()));
    r.step(format!(
        "C1 PUT y at Ra — Ra never saw C1, re-infers (C1,1): {}",
        sa[0].0
    ));
    assert_eq!(sa[0].0, vv(&[(c1(), 1), (c3(), 1)]));

    // anti-entropy: y falsely dominates v
    m.merge(&mut sb, &sa);
    assert!(
        !m.values(&sb).contains(&V),
        "Figure 4's anomaly: v lost, dominated by y"
    );
    r.step("anti-entropy: y {(C1,1),(C3,1)} falsely dominates v {(C1,1)} — v lost".into());
    r.finals.push(format!(
        "Rb: {}",
        sb.iter().map(|(h, v)| format!("{}:{}", name(*v), h)).collect::<Vec<_>>().join(" ")
    ));
    r
}

/// Figure 7: the full run under dotted version vectors, including the
/// anti-entropy extension and the final reconciliation write z.
pub fn fig7() -> FigureReport {
    let m = DvvMech;
    let mut r = FigureReport::new("Figure 7 — dotted version vectors");
    let mut sa: <DvvMech as Mechanism>::State = Vec::new();
    let mut sb: <DvvMech as Mechanism>::State = Vec::new();
    let empty = Default::default();

    m.write(&mut sb, &empty, V, rb(), &WriteMeta::basic(c1()));
    r.step(format!("C1 PUT v at Rb -> {}", sb[0].0));
    assert_eq!(sb[0].0, dvv(&[], Some((rb(), 1))));

    m.write(&mut sa, &empty, X, ra(), &WriteMeta::basic(c3()));
    r.step(format!("C3 PUT x at Ra -> {}", sa[0].0));
    assert_eq!(sa[0].0, dvv(&[], Some((ra(), 1))));

    m.write(&mut sb, &empty, W, rb(), &WriteMeta::basic(c2()));
    r.step(format!("C2 PUT w at Rb -> {} (v kept: same-server concurrency!)", sb[1].0));
    assert_eq!(sb.len(), 2);
    assert_eq!(sb[1].0, dvv(&[], Some((rb(), 2))));

    let (vals, ctx) = m.read(&sa);
    assert_eq!(vals, vec![X]);
    m.write(&mut sa, &ctx, Y, ra(), &WriteMeta::basic(c1()));
    r.step(format!("C1 GET at Ra (ctx {ctx}), PUT y -> {}", sa[0].0));
    assert_eq!(sa.len(), 1);
    assert_eq!(sa[0].0, dvv(&[(ra(), 1)], Some((ra(), 2))));

    // anti-entropy: Rb pushes its state to Ra
    let sb_snapshot = sb.clone();
    m.merge(&mut sa, &sb_snapshot);
    r.step(format!(
        "anti-entropy Rb→Ra: Ra now holds {} siblings (y, v, w)",
        sa.len()
    ));
    assert_eq!(sa.len(), 3);

    // C2 reads at Rb, writes z at Ra
    let (_, ctx_b) = m.read(&sb);
    assert_eq!(ctx_b, vv(&[(rb(), 2)]));
    m.write(&mut sa, &ctx_b, Z, ra(), &WriteMeta::basic(c2()));
    r.step(format!(
        "C2 GET at Rb (ctx {ctx_b}), PUT z at Ra -> z subsumes v,w; concurrent with y"
    ));
    assert_eq!(sa.len(), 2);
    let z = sa.iter().find(|(_, v)| *v == Z).map(|(d, _)| d.clone()).unwrap();
    let y = sa.iter().find(|(_, v)| *v == Y).map(|(d, _)| d.clone()).unwrap();
    assert_eq!(z, dvv(&[(rb(), 2)], Some((ra(), 3))));
    assert_eq!(y.compare(&z), ClockOrd::Concurrent);

    r.finals.push(format!(
        "Ra: {}",
        sa.iter().map(|(d, v)| format!("{}:{}", name(*v), d)).collect::<Vec<_>>().join(" ")
    ));
    r.finals.push(format!(
        "Rb: {}",
        sb.iter().map(|(d, v)| format!("{}:{}", name(*v), d)).collect::<Vec<_>>().join(" ")
    ));
    r
}

/// Replay a figure by number (1, 2, 3, 4, 7).
pub fn replay(fig: u32) -> crate::Result<FigureReport> {
    match fig {
        1 => Ok(fig1()),
        2 => Ok(fig2()),
        3 => Ok(fig3()),
        4 => Ok(fig4()),
        7 => Ok(fig7()),
        other => Err(crate::Error::Config(format!(
            "figure {other} is not replayable (valid: 1, 2, 3, 4, 7; \
             figures 5/6 are exercised by the simulator's quorum tests)"
        ))),
    }
}

/// All replayable figure numbers.
pub const REPLAYABLE: [u32; 5] = [1, 2, 3, 4, 7];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_figures_replay_and_render() {
        for fig in REPLAYABLE {
            let rep = replay(fig).unwrap();
            let text = rep.render();
            assert!(text.contains("Figure"), "{text}");
            assert!(!rep.steps.is_empty());
            assert!(!rep.finals.is_empty());
        }
    }

    #[test]
    fn invalid_figures_rejected() {
        assert!(replay(5).is_err());
        assert!(replay(6).is_err());
        assert!(replay(99).is_err());
    }

    #[test]
    fn fig3_and_fig7_disagree_on_v() {
        // the crux of the paper: same run, different survivors
        let f3 = fig3().render();
        let f7 = fig7().render();
        assert!(f3.contains("v lost"));
        assert!(f7.contains("v kept") || f7.contains("siblings"));
    }
}
