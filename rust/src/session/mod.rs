//! Client sessions: per-client contexts, write counters, and clock skew.
//!
//! The paper's client model (§2–§3): a client GETs, receives values plus
//! an opaque causal context, and supplies that context on its next PUT of
//! the same key. Sessions also record which value ids the client actually
//! observed — what the [`crate::oracle`] uses to derive true causality —
//! and, in *stateful* mode, the per-key write counters that make the
//! per-client-VV mechanism correct (§3.3).

use std::collections::HashMap;

use crate::clocks::Actor;
use crate::kernel::Mechanism;
use crate::store::Key;

/// What a completed PUT hands back to the session: the new write's id
/// plus, when the transport returns it, the coordinator's post-write
/// context. Passing the whole reply (instead of a bare `wrote_id`) is
/// what lets [`ClientSession::on_put_complete`] update itself.
#[derive(Debug, Clone)]
pub struct PutResult<M: Mechanism> {
    /// The id assigned to the written value.
    pub id: u64,
    /// The coordinator's post-write context, when the transport carries
    /// it back (`None` = the context is simply consumed).
    pub ctx: Option<M::Context>,
}

/// One client's session state.
#[derive(Debug, Clone)]
pub struct ClientSession<M: Mechanism> {
    /// The client's actor id.
    pub actor: Actor,
    /// Last received context per key.
    contexts: HashMap<Key, M::Context>,
    /// Value ids observed in the last GET per key.
    observed: HashMap<Key, Vec<u64>>,
    /// Per-key write counters (stateful clients, §3.3).
    write_counters: HashMap<Key, u64>,
    /// Fixed wall-clock skew (µs) applied to this client's timestamps.
    pub clock_skew_us: i64,
    /// Stateful clients carry their own counters; stateless ones force
    /// server-side inference (Figure 4).
    pub stateful: bool,
}

impl<M: Mechanism> ClientSession<M> {
    /// New session.
    pub fn new(actor: Actor, stateful: bool, clock_skew_us: i64) -> ClientSession<M> {
        ClientSession {
            actor,
            contexts: HashMap::new(),
            observed: HashMap::new(),
            write_counters: HashMap::new(),
            clock_skew_us,
            stateful,
        }
    }

    /// Record the outcome of a GET.
    pub fn on_get(&mut self, key: Key, ctx: M::Context, observed_ids: Vec<u64>) {
        self.contexts.insert(key, ctx);
        self.observed.insert(key, observed_ids);
    }

    /// Context to attach to a PUT of `key` (default when never read).
    pub fn context_for(&self, key: Key) -> M::Context {
        self.contexts.get(&key).cloned().unwrap_or_default()
    }

    /// Value ids the client observed for `key` (oracle input).
    pub fn observed_for(&self, key: Key) -> Vec<u64> {
        self.observed.get(&key).cloned().unwrap_or_default()
    }

    /// Next client-side write counter for `key`, or `None` when stateless.
    pub fn next_write_seq(&mut self, key: Key) -> Option<u64> {
        if self.stateful {
            let c = self.write_counters.entry(key).or_insert(0);
            *c += 1;
            Some(*c)
        } else {
            None
        }
    }

    /// Apply a completed PUT's [`PutResult`]. The reply itself carries
    /// everything the session needs — the new write's id and (optionally)
    /// the coordinator's post-write context — so callers no longer thread
    /// `wrote_id` by hand.
    ///
    /// Without a returned context it is consumed: the client's next blind
    /// write must not reuse a stale context unless it re-reads. (Riak
    /// semantics; keeps contexts fresh and mirrors §2's model where the
    /// client "maintains no state other than the context of the last
    /// GET".) A transport that *does* return the post-write context
    /// (Riak's return-body option; see [`crate::api::PutReply`]) replaces
    /// the stored one — never stale, it describes the client's own write.
    pub fn on_put_complete(&mut self, key: Key, res: &PutResult<M>) {
        // The client has trivially observed its own write.
        self.observed.insert(key, vec![res.id]);
        match &res.ctx {
            Some(ctx) => {
                self.contexts.insert(key, ctx.clone());
            }
            None => {
                self.contexts.remove(&key);
            }
        }
    }

    /// The skewed wall-clock reading for this client at simulated `now`.
    pub fn skewed_clock(&self, now_us: u64) -> u64 {
        (now_us as i64 + self.clock_skew_us).max(0) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::vv::vv;
    use crate::kernel::mechs::DvvMech;

    fn sess(stateful: bool) -> ClientSession<DvvMech> {
        ClientSession::new(Actor::client(0), stateful, 0)
    }

    #[test]
    fn context_defaults_to_empty() {
        let s = sess(true);
        assert_eq!(s.context_for(1), Default::default());
        assert!(s.observed_for(1).is_empty());
    }

    #[test]
    fn get_then_put_flow() {
        let mut s = sess(true);
        let ctx = vv(&[(Actor::server(0), 2)]);
        s.on_get(7, ctx.clone(), vec![100, 101]);
        assert_eq!(s.context_for(7), ctx);
        assert_eq!(s.observed_for(7), vec![100, 101]);
        s.on_put_complete(7, &PutResult { id: 102, ctx: None });
        assert_eq!(s.context_for(7), Default::default(), "context consumed");
        assert_eq!(s.observed_for(7), vec![102], "own write observed");
    }

    #[test]
    fn put_reply_context_replaces_stored_context() {
        let mut s = sess(true);
        s.on_get(7, vv(&[(Actor::server(0), 2)]), vec![100]);
        let fresh = vv(&[(Actor::server(0), 3)]);
        s.on_put_complete(7, &PutResult { id: 103, ctx: Some(fresh.clone()) });
        assert_eq!(s.context_for(7), fresh, "post-write context stored");
        assert_eq!(s.observed_for(7), vec![103]);
    }

    #[test]
    fn stateful_counters_increment_per_key() {
        let mut s = sess(true);
        assert_eq!(s.next_write_seq(1), Some(1));
        assert_eq!(s.next_write_seq(1), Some(2));
        assert_eq!(s.next_write_seq(2), Some(1));
    }

    #[test]
    fn stateless_clients_have_no_counter() {
        let mut s = sess(false);
        assert_eq!(s.next_write_seq(1), None);
    }

    #[test]
    fn skewed_clock_applies_offset() {
        let mut s = sess(true);
        s.clock_skew_us = -500;
        assert_eq!(s.skewed_clock(1000), 500);
        assert_eq!(s.skewed_clock(100), 0, "clamped at zero");
        s.clock_skew_us = 250;
        assert_eq!(s.skewed_clock(1000), 1250);
    }
}
