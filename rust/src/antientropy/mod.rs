//! Bulk anti-entropy for DVV stores: the compute hot spot the AOT XLA
//! path accelerates (DESIGN.md E10).
//!
//! When two replicas exchange state they must `sync` the sibling sets of
//! every divergent key — thousands of pairwise DVV dominance checks per
//! exchange. This module implements that bulk step twice over identical
//! semantics:
//!
//! * [`sync_scalar`] — the plain rust path (the same `kernel::ops` used on
//!   the request path);
//! * [`sync_xla`] — one batched dominance-kernel execution over *all*
//!   keys' clocks, with the keep-reduction done per key block (clocks of
//!   different keys must never interact, so the N×M code matrix is
//!   consumed block-diagonally).
//!
//! `benches/antientropy.rs` measures the crossover batch size between the
//! two; `examples/antientropy_accel.rs` demos the XLA path end to end.
//!
//! Worklists come from two interchangeable detectors:
//!
//! * the **scan path** — [`diff_pairs`] (whole store) or
//!   [`diff_pairs_in_shard`] (one backend shard at a time) walks every
//!   key on both sides: exact, O(keyspace) per round;
//! * the **tree path** — [`diff_pairs_merkle`] /
//!   [`diff_pairs_in_shard_merkle`] compares the incremental hash trees
//!   the backends maintain on the write path ([`merkle`]) and re-checks
//!   only the keys under diverged subtrees: O(log n) digests for a
//!   quiesced pair, O(divergence · log n) otherwise, with a ~2⁻⁶⁴
//!   per-comparison false-prune probability.
//!
//! Both emit the *same* worklist shape (and, up to that collision bound,
//! the same worklist — property-tested in `rust/tests/merkle_ae.rs`), so
//! the sync step is oblivious to which detector ran. The shard-level
//! variants are the unit the TCP server's [`anti_entropy_round`] batches
//! through [`KeyStore::merge_batch`], so reconciliation takes one
//! stripe-lock round per shard rather than one lock per key. In the
//! threaded cluster a pair exchange only runs when the chaos fabric
//! ([`crate::server::fabric::Fabric`]) delivers both directions of the
//! link that round — crashed or partitioned replicas simply miss the
//! round and catch up after healing.
//!
//! [`anti_entropy_round`]: crate::server::LocalCluster::anti_entropy_round
//! [`KeyStore::merge_batch`]: crate::store::KeyStore::merge_batch

pub mod merkle;

use crate::clocks::dvv::Dvv;
use crate::error::Result;
use crate::kernel::mechanism::Val;
use crate::kernel::ops;
use crate::runtime::batch::SlotMap;
use crate::runtime::XlaEngine;
use crate::store::Key;

/// One key's divergent sibling sets on the two sides of an exchange.
#[derive(Debug, Clone)]
pub struct KeyPair {
    /// The key.
    pub key: Key,
    /// Local sibling set.
    pub local: Vec<(Dvv, Val)>,
    /// Remote sibling set.
    pub remote: Vec<(Dvv, Val)>,
}

/// Result: the merged sibling set per key.
pub type Merged = Vec<(Key, Vec<(Dvv, Val)>)>;

/// Scalar reference path: per-key kernel `sync`.
pub fn sync_scalar(pairs: &[KeyPair]) -> Merged {
    pairs
        .iter()
        .map(|p| {
            let mut merged = p.local.clone();
            ops::sync_into(&mut merged, &p.remote);
            (p.key, merged)
        })
        .collect()
}

/// XLA path: concatenate every key's clocks into one (A, B) batch pair,
/// run the dominance kernel once, and reduce keep-masks block-diagonally.
///
/// Precondition (the §4 store invariant, upheld by every mechanism
/// `write`/`merge`): each side's sibling set is pairwise concurrent. The
/// kernel compares local × remote only, so *within-set* dominance — which
/// cannot occur in valid states — would not be winnowed here, while
/// [`sync_scalar`] would incidentally remove it.
///
/// Falls back to [`sync_scalar`] per oversized chunk when a batch exceeds
/// the largest compiled variant.
pub fn sync_xla(engine: &mut XlaEngine, pairs: &[KeyPair], slots: &SlotMap) -> Result<Merged> {
    // find the largest variant once to size chunks
    let max_n = engine
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.kind == "bulk_sync" && a.r >= slots.len())
        .map(|a| a.n.min(a.m))
        .max()
        .unwrap_or(0);
    if max_n == 0 {
        return Ok(sync_scalar(pairs));
    }

    let mut out: Merged = Vec::with_capacity(pairs.len());
    let mut chunk: Vec<&KeyPair> = Vec::new();
    let (mut na, mut nb) = (0usize, 0usize);
    for p in pairs {
        let (la, lb) = (p.local.len(), p.remote.len());
        if la > max_n || lb > max_n {
            // single key too large for any variant: scalar fallback
            flush_chunk(engine, slots, &mut chunk, &mut out)?;
            na = 0;
            nb = 0;
            let mut merged = p.local.clone();
            ops::sync_into(&mut merged, &p.remote);
            out.push((p.key, merged));
            continue;
        }
        if na + la > max_n || nb + lb > max_n {
            flush_chunk(engine, slots, &mut chunk, &mut out)?;
            na = 0;
            nb = 0;
        }
        chunk.push(p);
        na += la;
        nb += lb;
    }
    flush_chunk(engine, slots, &mut chunk, &mut out)?;
    Ok(out)
}

fn flush_chunk(
    engine: &mut XlaEngine,
    slots: &SlotMap,
    chunk: &mut Vec<&KeyPair>,
    out: &mut Merged,
) -> Result<()> {
    if chunk.is_empty() {
        return Ok(());
    }
    // concatenate
    let mut a: Vec<Dvv> = Vec::new();
    let mut b: Vec<Dvv> = Vec::new();
    let mut blocks: Vec<(usize, usize, usize, usize)> = Vec::new(); // (a0, a1, b0, b1)
    for p in chunk.iter() {
        let a0 = a.len();
        let b0 = b.len();
        a.extend(p.local.iter().map(|(c, _)| c.clone()));
        b.extend(p.remote.iter().map(|(c, _)| c.clone()));
        blocks.push((a0, a.len(), b0, b.len()));
    }
    let codes = engine.dominance_codes(&a, &b, slots)?;
    let bw = b.len(); // code-matrix row width

    for (p, &(a0, a1, b0, b1)) in chunk.iter().zip(blocks.iter()) {
        let mut merged: Vec<(Dvv, Val)> = Vec::with_capacity((a1 - a0) + (b1 - b0));
        // keep local unless strictly dominated by a remote clock of the
        // same key (code 1)
        for (i, item) in p.local.iter().enumerate() {
            let row = &codes[(a0 + i) * bw..(a0 + i) * bw + bw];
            let dominated = row[b0..b1].iter().any(|&c| c == 1);
            if !dominated {
                merged.push(item.clone());
            }
        }
        // keep remote unless dominated-or-equal by a local clock (bit 2)
        for (j, item) in p.remote.iter().enumerate() {
            let covered = (a0..a1).any(|i| codes[i * bw + b0 + j] & 2 != 0);
            if !covered {
                merged.push(item.clone());
            }
        }
        out.push((p.key, merged));
    }
    chunk.clear();
    Ok(())
}

/// Order-insensitive sibling-set equality. Replica-to-replica `merge`
/// appends survivors in local-first order, so two converged replicas can
/// hold the same sibling set in different `Vec` orders; comparing
/// verbatim would report divergence forever. Sets are small (bounded by
/// true concurrency), so the quadratic scan is fine.
pub fn same_siblings(l: &[(Dvv, Val)], r: &[(Dvv, Val)]) -> bool {
    l.len() == r.len() && l.iter().all(|item| r.contains(item))
}

fn diff_keys<BL, BR>(
    local: &crate::store::KeyStore<crate::kernel::mechs::DvvMech, BL>,
    remote: &crate::store::KeyStore<crate::kernel::mechs::DvvMech, BR>,
    mut keys: Vec<Key>,
) -> Vec<KeyPair>
where
    BL: crate::store::StorageBackend<crate::kernel::mechs::DvvMech>,
    BR: crate::store::StorageBackend<crate::kernel::mechs::DvvMech>,
{
    keys.sort_unstable();
    keys.dedup();
    keys.into_iter()
        .filter_map(|key| {
            let l = local.state(key);
            let r = remote.state(key);
            if same_siblings(&l, &r) {
                None
            } else {
                Some(KeyPair { key, local: l, remote: r })
            }
        })
        .collect()
}

/// Build the divergent-key worklist for an exchange between two DVV
/// key-stores: keys where the sibling clock sets differ.
pub fn diff_pairs<BL, BR>(
    local: &crate::store::KeyStore<crate::kernel::mechs::DvvMech, BL>,
    remote: &crate::store::KeyStore<crate::kernel::mechs::DvvMech, BR>,
) -> Vec<KeyPair>
where
    BL: crate::store::StorageBackend<crate::kernel::mechs::DvvMech>,
    BR: crate::store::StorageBackend<crate::kernel::mechs::DvvMech>,
{
    diff_keys(local, remote, local.keys().chain(remote.keys()).collect())
}

/// Divergent-key worklist restricted to one of `local`'s backend shards —
/// the unit of work for incremental anti-entropy over a sharded store
/// (see [`crate::server::LocalCluster::anti_entropy_round`]). Remote keys
/// absent locally are included when they fall in `shard` under `local`'s
/// key partition, so the shards' worklists cover the full exchange.
///
/// When both stores have the same shard count, the
/// [`StorageBackend`](crate::store::StorageBackend) contract guarantees
/// identical key partitions, so only the matching remote shard is
/// snapshotted; otherwise the remote key set is filtered through
/// `local`'s partition.
pub fn diff_pairs_in_shard<BL, BR>(
    local: &crate::store::KeyStore<crate::kernel::mechs::DvvMech, BL>,
    remote: &crate::store::KeyStore<crate::kernel::mechs::DvvMech, BR>,
    shard: usize,
) -> Vec<KeyPair>
where
    BL: crate::store::StorageBackend<crate::kernel::mechs::DvvMech>,
    BR: crate::store::StorageBackend<crate::kernel::mechs::DvvMech>,
{
    let mut keys = local.keys_in_shard(shard);
    if remote.shard_count() == local.shard_count() {
        keys.extend(remote.keys_in_shard(shard));
    } else {
        keys.extend(remote.keys().filter(|&k| local.shard_of(k) == shard));
    }
    diff_keys(local, remote, keys)
}

/// Tree-walk variant of [`diff_pairs`]: compare the two stores'
/// incremental hash trees shard by shard, then re-check only the flagged
/// keys' states. Emits the identical worklist (same keys, same order,
/// same sibling snapshots) — the tree walk yields a *candidate* superset
/// and the final [`same_siblings`] filter plus global sort are shared
/// with the scan path, so the two differ only if a 2⁻⁶⁴ digest collision
/// prunes real divergence.
///
/// Per-shard trees only align when the two backends agree on the key
/// partition, i.e. when their shard counts match
/// ([`StorageBackend`](crate::store::StorageBackend) contract); on a
/// mismatch this falls back to the scan path.
pub fn diff_pairs_merkle<BL, BR>(
    local: &crate::store::KeyStore<crate::kernel::mechs::DvvMech, BL>,
    remote: &crate::store::KeyStore<crate::kernel::mechs::DvvMech, BR>,
) -> Vec<KeyPair>
where
    BL: crate::store::StorageBackend<crate::kernel::mechs::DvvMech>,
    BR: crate::store::StorageBackend<crate::kernel::mechs::DvvMech>,
{
    if local.shard_count() != remote.shard_count() {
        return diff_pairs(local, remote);
    }
    let mut keys = Vec::new();
    for shard in 0..local.shard_count() {
        keys.extend(merkle_candidates(local, remote, shard));
    }
    diff_keys(local, remote, keys)
}

/// Tree-walk variant of [`diff_pairs_in_shard`]; same worklist, same
/// fallback rule as [`diff_pairs_merkle`].
pub fn diff_pairs_in_shard_merkle<BL, BR>(
    local: &crate::store::KeyStore<crate::kernel::mechs::DvvMech, BL>,
    remote: &crate::store::KeyStore<crate::kernel::mechs::DvvMech, BR>,
    shard: usize,
) -> Vec<KeyPair>
where
    BL: crate::store::StorageBackend<crate::kernel::mechs::DvvMech>,
    BR: crate::store::StorageBackend<crate::kernel::mechs::DvvMech>,
{
    if local.shard_count() != remote.shard_count() {
        return diff_pairs_in_shard(local, remote, shard);
    }
    diff_keys(local, remote, merkle_candidates(local, remote, shard))
}

/// Candidate keys for one matching shard pair, via the tree walk. Holds
/// `local`'s stripe lock, then `remote`'s (see the [`merkle`] module
/// docs for the lock discipline).
fn merkle_candidates<BL, BR>(
    local: &crate::store::KeyStore<crate::kernel::mechs::DvvMech, BL>,
    remote: &crate::store::KeyStore<crate::kernel::mechs::DvvMech, BR>,
    shard: usize,
) -> Vec<Key>
where
    BL: crate::store::StorageBackend<crate::kernel::mechs::DvvMech>,
    BR: crate::store::StorageBackend<crate::kernel::mechs::DvvMech>,
{
    local.backend().with_merkle(shard, |tl| {
        remote.backend().with_merkle(shard, |tr| merkle::diff(tl, tr).0)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::dvv::dvv;
    use crate::clocks::Actor;
    use crate::runtime::artifact;
    use crate::testkit::Rng;

    fn a() -> Actor {
        Actor::server(0)
    }
    fn b() -> Actor {
        Actor::server(1)
    }
    fn v(id: u64) -> Val {
        Val::new(id, 0)
    }

    fn sample_pairs() -> Vec<KeyPair> {
        vec![
            // concurrent siblings: both survive
            KeyPair {
                key: 1,
                local: vec![(dvv(&[], Some((a(), 1))), v(1))],
                remote: vec![(dvv(&[], Some((b(), 1))), v(2))],
            },
            // remote dominates local
            KeyPair {
                key: 2,
                local: vec![(dvv(&[], Some((b(), 1))), v(3))],
                remote: vec![(dvv(&[(b(), 2)], Some((a(), 1))), v(4))],
            },
            // equal histories: local copy kept
            KeyPair {
                key: 3,
                local: vec![(dvv(&[(a(), 2)], None), v(5))],
                remote: vec![(dvv(&[(a(), 1)], Some((a(), 2))), v(6))],
            },
        ]
    }

    #[test]
    fn scalar_sync_per_key() {
        let merged = sync_scalar(&sample_pairs());
        assert_eq!(merged[0].1.len(), 2);
        assert_eq!(merged[1].1.len(), 1);
        assert_eq!(merged[1].1[0].1, v(4));
        assert_eq!(merged[2].1.len(), 1);
        assert_eq!(merged[2].1[0].1, v(5), "equal keeps the local copy");
    }

    #[test]
    fn cross_key_isolation_in_scalar_path() {
        // key 10's clock would dominate key 11's if they interacted
        let pairs = vec![
            KeyPair {
                key: 10,
                local: vec![(dvv(&[(a(), 9)], None), v(1))],
                remote: vec![],
            },
            KeyPair {
                key: 11,
                local: vec![],
                remote: vec![(dvv(&[(a(), 1)], None), v(2))],
            },
        ];
        let merged = sync_scalar(&pairs);
        assert_eq!(merged[1].1.len(), 1, "key 11's value must survive");
    }

    #[test]
    fn xla_matches_scalar_when_artifacts_present() {
        if !artifact::default_dir().join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut eng = XlaEngine::open(&artifact::default_dir()).unwrap();
        let slots = SlotMap::dense(8);
        let pairs = sample_pairs();
        let scalar = sync_scalar(&pairs);
        let xla = sync_xla(&mut eng, &pairs, &slots).unwrap();
        assert_eq!(canon(scalar), canon(xla));
    }

    #[test]
    fn xla_cross_key_isolation() {
        if !artifact::default_dir().join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut eng = XlaEngine::open(&artifact::default_dir()).unwrap();
        let slots = SlotMap::dense(8);
        // key 20's big clock must not kill key 21's small one
        let pairs = vec![
            KeyPair {
                key: 20,
                local: vec![(dvv(&[(a(), 9)], Some((b(), 1))), v(1))],
                remote: vec![(dvv(&[], Some((b(), 2))), v(2))],
            },
            KeyPair {
                key: 21,
                local: vec![(dvv(&[], Some((a(), 1))), v(3))],
                remote: vec![(dvv(&[], Some((b(), 1))), v(4))],
            },
        ];
        let xla = sync_xla(&mut eng, &pairs, &slots).unwrap();
        let k21 = xla.iter().find(|(k, _)| *k == 21).unwrap();
        assert_eq!(k21.1.len(), 2, "cross-key dominance leaked: {xla:?}");
    }

    #[test]
    fn xla_random_multikey_matches_scalar() {
        if !artifact::default_dir().join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut eng = XlaEngine::open(&artifact::default_dir()).unwrap();
        let slots = SlotMap::dense(8);
        let mut rng = Rng::new(77);
        let mut next_id = 1u64;
        let mut gen_set = |rng: &mut Rng, next_id: &mut u64| -> Vec<(Dvv, Val)> {
            let mut set: Vec<(Dvv, Val)> = Vec::new();
            for _ in 0..rng.range(0, 4) {
                let vvp = crate::clocks::VersionVector::from_pairs(
                    (0..4u32).map(|i| (Actor::server(i), rng.below(4))),
                );
                let r = Actor::server(rng.below(4) as u32);
                let n = vvp.get(r) + 1 + rng.below(2);
                *next_id += 1;
                let clock = Dvv { vv: vvp, dot: Some((r, n)) };
                // uphold the store invariant: sibling sets are pairwise
                // concurrent (what real mechanism states always satisfy)
                crate::kernel::ops::insert_candidate(&mut set, clock, v(*next_id));
            }
            set
        };
        let pairs: Vec<KeyPair> = (0..200)
            .map(|key| KeyPair {
                key,
                local: gen_set(&mut rng, &mut next_id),
                remote: gen_set(&mut rng, &mut next_id),
            })
            .collect();
        let scalar = sync_scalar(&pairs);
        let xla = sync_xla(&mut eng, &pairs, &slots).unwrap();
        assert_eq!(canon(scalar), canon(xla));
    }

    fn canon(mut m: Merged) -> Vec<(Key, Vec<u64>)> {
        m.sort_by_key(|(k, _)| *k);
        m.into_iter()
            .map(|(k, set)| {
                let mut ids: Vec<u64> = set.iter().map(|(_, v)| v.id).collect();
                ids.sort_unstable();
                (k, ids)
            })
            .collect()
    }

    #[test]
    fn diff_pairs_finds_divergence() {
        use crate::kernel::mechs::DvvMech;
        use crate::kernel::{Mechanism, WriteMeta};
        use crate::store::KeyStore;
        let mech = DvvMech;
        let s1 = KeyStore::new(mech);
        let s2 = KeyStore::new(mech);
        let empty = <DvvMech as Mechanism>::Context::default();
        let meta = WriteMeta::basic(Actor::client(0));
        s1.write(1, &empty, v(1), a(), &meta);
        s2.write(1, &empty, v(2), b(), &meta);
        s1.write(2, &empty, v(3), a(), &meta); // only on s1
        // identical key on both sides
        s1.write(3, &empty, v(4), a(), &meta);
        let st = s1.state(3);
        s2.merge_key(3, &st);
        let pairs = diff_pairs(&s1, &s2);
        let keys: Vec<Key> = pairs.iter().map(|p| p.key).collect();
        assert_eq!(keys, vec![1, 2], "key 3 converged, 1/2 divergent");
    }

    #[test]
    fn same_siblings_ignores_order() {
        let x = (dvv(&[], Some((a(), 1))), v(1));
        let y = (dvv(&[], Some((b(), 1))), v(2));
        assert!(same_siblings(&[x.clone(), y.clone()], &[y.clone(), x.clone()]));
        assert!(!same_siblings(&[x.clone()], &[y.clone()]));
        assert!(!same_siblings(&[x.clone()], &[x, y]));
        assert!(same_siblings(&[], &[]));
    }

    #[test]
    fn converged_but_reordered_replicas_show_no_divergence() {
        use crate::kernel::mechs::DvvMech;
        use crate::kernel::{Mechanism, WriteMeta};
        use crate::store::KeyStore;
        let s1 = KeyStore::new(DvvMech);
        let s2 = KeyStore::new(DvvMech);
        let empty = <DvvMech as Mechanism>::Context::default();
        let meta = WriteMeta::basic(Actor::client(0));
        // concurrent writes on opposite replicas, then a full exchange:
        // both hold {x, y} but in opposite insertion orders
        s1.write(1, &empty, v(1), a(), &meta);
        s2.write(1, &empty, v(2), b(), &meta);
        let (st1, st2) = (s1.state(1), s2.state(1));
        s1.merge_key(1, &st2);
        s2.merge_key(1, &st1);
        assert_eq!(s1.values(1).len(), 2);
        assert!(diff_pairs(&s1, &s2).is_empty(), "order alone is not divergence");
    }

    #[test]
    fn shard_worklists_cover_the_full_diff() {
        use crate::kernel::mechs::DvvMech;
        use crate::kernel::{Mechanism, WriteMeta};
        use crate::store::{KeyStore, ShardedBackend};
        let local = KeyStore::with_backend(DvvMech, ShardedBackend::with_shards(4));
        let remote = KeyStore::new(DvvMech);
        let empty = <DvvMech as Mechanism>::Context::default();
        let meta = WriteMeta::basic(Actor::client(0));
        for k in 0..32u64 {
            local.write(k, &empty, v(k + 1), a(), &meta);
        }
        // remote-only key, absent locally: still lands in some shard's list
        remote.write(100, &empty, v(200), b(), &meta);

        let whole = diff_pairs(&local, &remote);
        let mut sharded: Vec<Key> = (0..local.shard_count())
            .flat_map(|s| diff_pairs_in_shard(&local, &remote, s))
            .map(|p| p.key)
            .collect();
        sharded.sort_unstable();
        let mut expect: Vec<Key> = whole.iter().map(|p| p.key).collect();
        expect.sort_unstable();
        assert_eq!(sharded, expect);
        assert!(expect.contains(&100));
        // each shard's worklist only holds keys it owns
        for s in 0..local.shard_count() {
            for p in diff_pairs_in_shard(&local, &remote, s) {
                assert_eq!(local.shard_of(p.key), s);
            }
        }
    }
}
