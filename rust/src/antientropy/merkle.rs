//! Incremental hash trees over the key space: O(log n) divergence
//! detection for anti-entropy (the Riak/bigsets "hashtree" idea).
//!
//! [`diff_pairs`](super::diff_pairs) scans whole stores, so every AE
//! round costs O(keyspace) even when nothing diverged. A [`ShardTree`]
//! summarizes one backend shard as a fixed-fanout tree of 64-bit
//! digests; two replicas compare roots, descend only into subtrees whose
//! digests differ, and end at the handful of leaves that actually hold
//! divergent keys. A quiesced pair's exchange is one root comparison.
//!
//! ## Shape
//!
//! The tree is a radix-16 trie of depth [`DEPTH`] over the top
//! [`LEAF_BITS`] bits of `mix64(key)`: 65 536 leaves, each covering a
//! uniform slice of the (hashed) key space. Interior levels are stored
//! sparsely (`HashMap` per level) so an empty or small shard costs O(keys
//! stored), not O(tree size).
//!
//! ## Digests compose by addition
//!
//! Every stored key contributes one well-mixed term,
//! `digest::leaf(key, M::state_digest(state))`, and every node's digest
//! is the **wrapping sum** of the terms below it. Addition is
//! order-independent and invertible, which buys two things:
//!
//! * *incremental maintenance*: replacing a key's term is
//!   `sum - old + new` on the leaf plus the same delta on the O([`DEPTH`])
//!   ancestors (deltas are batched in a dirty-leaf map and propagated on
//!   the next read, so a write is O(1) plus amortized O(depth));
//! * *composability*: a whole store's root is the sum of its shard
//!   roots — comparable across replicas with different shard counts or
//!   backend types, because the sum only depends on the key/state
//!   multiset.
//!
//! The price is probabilistic equality: two different subtrees collide
//! with probability ~2⁻⁶⁴ per comparison, in which case the walk prunes
//! real divergence until a later write reshuffles the digests. This is
//! the same bet the Riak hashtree lineage makes; the scan path
//! ([`super::diff_pairs`]) remains available as the exact oracle.
//!
//! Lock discipline: backends run [`ShardTree`] methods under their
//! stripe locks (see
//! [`StorageBackend::with_merkle`](crate::store::StorageBackend::with_merkle)),
//! and a tree diff holds *two* stores' locks (local then remote). AE
//! rounds are sequential per pair, so the nesting is never reversed
//! concurrently; never diff a store against itself.

use std::collections::HashMap;

use crate::kernel::digest;
use crate::store::Key;

/// log₂ of the tree fanout (16 children per interior node).
pub const FANOUT_BITS: u32 = 4;

/// Interior levels between the root and the leaves.
pub const DEPTH: u32 = 4;

/// Bits of `mix64(key)` used to address a leaf (16 → 65 536 leaves).
pub const LEAF_BITS: u32 = FANOUT_BITS * DEPTH;

/// The leaf slot a key hashes to.
fn leaf_of(key: Key) -> u64 {
    digest::mix64(key) >> (64 - LEAF_BITS)
}

#[derive(Debug, Clone, Default)]
struct Leaf {
    /// Wrapping sum of `keys` values.
    sum: u64,
    /// Per-key leaf digest ([`digest::leaf`]); mirrors the backend map.
    keys: HashMap<Key, u64>,
}

/// Counters from one tree walk, for tests and the scale bench: a
/// quiesced pair shows `nodes_compared == 1`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DiffStats {
    /// Digest comparisons performed (interior + leaf sums).
    pub nodes_compared: u64,
    /// Leaves whose per-key maps were compared entry by entry.
    pub leaves_compared: u64,
    /// Candidate keys emitted.
    pub keys_flagged: usize,
}

/// The incremental hash tree summarizing one backend shard.
#[derive(Debug, Clone, Default)]
pub struct ShardTree {
    /// Leaf slots, sparse: absent slot ≡ sum 0, no keys.
    leaves: HashMap<u64, Leaf>,
    /// Interior sums per level; `levels[0]` is the root level (index 0),
    /// `levels[l]` has up to 16ˡ populated nodes. Absent ≡ 0 (a node
    /// whose deltas cancelled is equal to one never touched).
    levels: Vec<HashMap<u64, u64>>,
    /// Dirty-leaf deltas not yet propagated to interior levels.
    pending: HashMap<u64, u64>,
}

impl ShardTree {
    /// Empty tree.
    pub fn new() -> ShardTree {
        ShardTree {
            leaves: HashMap::new(),
            levels: (0..DEPTH).map(|_| HashMap::new()).collect(),
            pending: HashMap::new(),
        }
    }

    /// Record `key`'s current state digest (as produced by
    /// `Mechanism::state_digest`), replacing any previous term for the
    /// key. O(1): the interior update is deferred to [`flush`].
    ///
    /// [`flush`]: ShardTree::flush
    pub fn record(&mut self, key: Key, state_digest: u64) {
        let slot = leaf_of(key);
        let leaf = self.leaves.entry(slot).or_default();
        let term = digest::leaf(key, state_digest);
        let old = leaf.keys.insert(key, term).unwrap_or(0);
        let delta = term.wrapping_sub(old);
        if delta == 0 {
            return;
        }
        leaf.sum = leaf.sum.wrapping_add(delta);
        let e = self.pending.entry(slot).or_insert(0);
        *e = e.wrapping_add(delta);
    }

    /// Drop everything (the shard was wiped).
    pub fn clear(&mut self) {
        *self = ShardTree::new();
    }

    /// Rebuild from scratch over `(key, state_digest)` items — what a
    /// durable shard does after WAL replay, and what the property tests
    /// compare the incremental tree against.
    pub fn rebuild(items: impl IntoIterator<Item = (Key, u64)>) -> ShardTree {
        let mut t = ShardTree::new();
        for (key, sd) in items {
            t.record(key, sd);
        }
        t
    }

    /// Propagate pending leaf deltas up the interior levels: O(depth)
    /// per dirty leaf, amortizing bursts of writes to the same leaf.
    fn flush(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        for (slot, delta) in self.pending.drain() {
            if delta == 0 {
                continue;
            }
            for (l, level) in self.levels.iter_mut().enumerate() {
                let idx = slot >> (FANOUT_BITS * (DEPTH - l as u32));
                let e = level.entry(idx).or_insert(0);
                *e = e.wrapping_add(delta);
            }
        }
    }

    /// Root digest: the wrapping sum of every stored key's leaf term.
    /// 0 for an empty shard.
    pub fn root(&mut self) -> u64 {
        self.flush();
        self.levels[0].get(&0).copied().unwrap_or(0)
    }

    /// Number of keys the tree currently covers.
    pub fn key_count(&self) -> usize {
        self.leaves.values().map(|l| l.keys.len()).sum()
    }

    /// Digest of node `idx` at `level` (`level == DEPTH` addresses leaf
    /// sums). Absent nodes read as 0.
    fn node(&self, level: u32, idx: u64) -> u64 {
        if level == DEPTH {
            self.leaves.get(&idx).map(|l| l.sum).unwrap_or(0)
        } else {
            self.levels[level as usize].get(&idx).copied().unwrap_or(0)
        }
    }
}

/// Walk two trees top-down, descending only where digests differ, and
/// return the keys that may diverge (a superset of the true divergence
/// set: a leaf-term mismatch flags the key, but the caller still
/// re-checks states — see [`super::diff_pairs_merkle`]).
///
/// Keys present on one side only are flagged too (their term is compared
/// against the absent side's implicit 0).
pub fn diff(a: &mut ShardTree, b: &mut ShardTree) -> (Vec<Key>, DiffStats) {
    a.flush();
    b.flush();
    let mut stats = DiffStats::default();
    let mut keys = Vec::new();
    // (level, idx) nodes whose digests are known to differ get their
    // children probed; the walk starts by probing the root itself.
    let mut stack: Vec<(u32, u64)> = vec![(0, 0)];
    while let Some((level, idx)) = stack.pop() {
        stats.nodes_compared += 1;
        if a.node(level, idx) == b.node(level, idx) {
            continue; // identical subtree: prune
        }
        if level == DEPTH {
            stats.leaves_compared += 1;
            let empty = Leaf::default();
            let la = a.leaves.get(&idx).unwrap_or(&empty);
            let lb = b.leaves.get(&idx).unwrap_or(&empty);
            for (&key, &term) in &la.keys {
                if lb.keys.get(&key).copied().unwrap_or(0) != term {
                    keys.push(key);
                }
            }
            for (&key, _) in &lb.keys {
                if !la.keys.contains_key(&key) {
                    keys.push(key);
                }
            }
        } else {
            for child in 0..(1u64 << FANOUT_BITS) {
                stack.push((level + 1, (idx << FANOUT_BITS) | child));
            }
        }
    }
    stats.keys_flagged = keys.len();
    (keys, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;

    fn filled(items: &[(Key, u64)]) -> ShardTree {
        ShardTree::rebuild(items.iter().copied())
    }

    #[test]
    fn empty_tree_root_is_zero() {
        assert_eq!(ShardTree::new().root(), 0);
    }

    #[test]
    fn root_is_order_independent() {
        let mut fwd = filled(&[(1, 10), (2, 20), (3, 30)]);
        let mut rev = filled(&[(3, 30), (1, 10), (2, 20)]);
        assert_eq!(fwd.root(), rev.root());
        assert_ne!(fwd.root(), 0);
    }

    #[test]
    fn incremental_update_matches_rebuild() {
        let mut t = filled(&[(1, 10), (2, 20)]);
        let _ = t.root(); // force a flush mid-history
        t.record(1, 11); // overwrite
        t.record(3, 30); // insert
        let mut fresh = filled(&[(1, 11), (2, 20), (3, 30)]);
        assert_eq!(t.root(), fresh.root());
    }

    #[test]
    fn rerecording_same_digest_changes_nothing() {
        let mut t = filled(&[(1, 10), (2, 20)]);
        let before = t.root();
        t.record(1, 10);
        assert_eq!(t.root(), before);
        assert!(t.pending.is_empty());
    }

    #[test]
    fn diff_of_identical_trees_prunes_at_the_root() {
        let mut a = filled(&[(1, 10), (2, 20), (3, 30)]);
        let mut b = filled(&[(3, 30), (2, 20), (1, 10)]);
        let (keys, stats) = diff(&mut a, &mut b);
        assert!(keys.is_empty());
        assert_eq!(stats.nodes_compared, 1, "quiesced pair = one comparison");
    }

    #[test]
    fn diff_flags_changed_missing_and_extra_keys() {
        let mut a = filled(&[(1, 10), (2, 20), (3, 30)]);
        let mut b = filled(&[(1, 10), (2, 21), (4, 40)]);
        let (mut keys, stats) = diff(&mut a, &mut b);
        keys.sort_unstable();
        assert_eq!(keys, vec![2, 3, 4]);
        assert_eq!(stats.keys_flagged, 3);
        assert!(stats.leaves_compared >= 1);
    }

    #[test]
    fn diff_against_empty_flags_everything() {
        let mut a = filled(&[(7, 70), (8, 80)]);
        let mut b = ShardTree::new();
        let (mut keys, _) = diff(&mut a, &mut b);
        keys.sort_unstable();
        assert_eq!(keys, vec![7, 8]);
        let (mut keys_rev, _) = diff(&mut b, &mut a);
        keys_rev.sort_unstable();
        assert_eq!(keys_rev, vec![7, 8], "diff is symmetric in flagged keys");
    }

    #[test]
    fn sum_of_roots_is_sharding_independent() {
        // one tree over all keys vs. keys split across two trees: the
        // additive root composes identically
        let items: Vec<(Key, u64)> = (0..100).map(|k| (k, k * 31 + 7)).collect();
        let mut whole = filled(&items);
        let mut even = filled(&items.iter().copied().filter(|(k, _)| k % 2 == 0).collect::<Vec<_>>());
        let mut odd = filled(&items.iter().copied().filter(|(k, _)| k % 2 == 1).collect::<Vec<_>>());
        assert_eq!(whole.root(), even.root().wrapping_add(odd.root()));
    }

    #[test]
    fn seeded_walk_cost_tracks_divergence_not_size() {
        let mut rng = Rng::new(42);
        let items: Vec<(Key, u64)> = (0..5_000).map(|k| (k, rng.next_u64())).collect();
        let mut a = filled(&items);
        let mut b = filled(&items);
        // perturb 5 keys on b
        for k in [10u64, 999, 2_500, 3_333, 4_999] {
            b.record(k, rng.next_u64());
        }
        let (mut keys, stats) = diff(&mut a, &mut b);
        keys.sort_unstable();
        assert_eq!(keys, vec![10, 999, 2_500, 3_333, 4_999]);
        // 5 divergent leaves → ≤ 5 root-to-leaf paths, each probing 16
        // children per interior node; far below the 5 000-key scan
        let bound = 1 + 5 * (DEPTH as u64) * 16;
        assert!(stats.nodes_compared <= bound, "{stats:?} vs bound {bound}");
    }

    #[test]
    fn clear_resets_to_empty() {
        let mut t = filled(&[(1, 10)]);
        assert_ne!(t.root(), 0);
        t.clear();
        assert_eq!(t.root(), 0);
        assert_eq!(t.key_count(), 0);
    }
}
