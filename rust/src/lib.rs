//! # dvv-store
//!
//! A Dynamo-style replicated key-value store framework with **pluggable
//! causality tracking**, reproducing *"Dotted Version Vectors: Logical
//! Clocks for Optimistic Replication"* (Preguiça, Baquero, Almeida, Fonte,
//! Gonçalves — 2010).
//!
//! The crate is organized around the paper's structure:
//!
//! * [`api`] — the canonical client surface: `KvClient` (GET returns
//!   siblings + an opaque, versioned `CausalCtx` token; PUT hands it
//!   back) implemented over three transports — the simulator, the
//!   threaded cluster, and live TCP — so workloads, fault schedules,
//!   and oracle audits run unchanged against all three.
//! * [`clocks`] — every causality mechanism the paper surveys (§3) plus the
//!   contribution (§5): causal histories (ground truth), physical-clock LWW,
//!   Lamport clocks, per-server version vectors, per-client version vectors,
//!   **dotted version vectors**, and the compact DVVSet extension.
//! * [`kernel`] — the eventual-consistency kernel of §4: `sync` and
//!   `update`, generic over the mechanism.
//! * [`store`], [`cluster`], [`net`], [`sim`], [`server`], [`coordinator`],
//!   [`antientropy`], [`session`] — the Dynamo/Riak-like substrate the paper
//!   assumes: versioned storage with siblings behind a pluggable
//!   [`store::StorageBackend`] (flat single-lock or lock-striped sharded),
//!   consistent-hashing ring, deterministic simulated network,
//!   discrete-event simulator, replica nodes, quorum get/put coordination
//!   (§4.1, Figures 5–6) with batched replication fan-out, anti-entropy,
//!   and client sessions.
//! * [`workload`], [`oracle`], [`metrics`], [`figures`] — experiment
//!   machinery: generators, the causal-history anomaly oracle, metric
//!   accounting, and executable replays of the paper's Figures 1–4 and 7.
//! * [`runtime`] — the PJRT bridge that loads AOT-compiled XLA artifacts
//!   (built once from JAX/Pallas by `make artifacts`) for the bulk
//!   anti-entropy path; python never runs on the request path.
//! * [`testkit`], [`bench_support`], [`cli`], [`config`] — in-tree
//!   substrates standing in for `rand`/`proptest`/`criterion`/`clap`/`serde`
//!   (unavailable in the offline build environment; see DESIGN.md §3).

pub mod antientropy;
pub mod api;
pub mod bench_support;
pub mod cli;
pub mod clocks;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod figures;
pub mod kernel;
pub mod metrics;
pub mod net;
pub mod oracle;
pub mod runtime;
pub mod server;
pub mod session;
pub mod sim;
pub mod store;
pub mod testkit;
pub mod workload;

pub use error::{Error, Result};
