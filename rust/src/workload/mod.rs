//! Workload generation: the op streams that drive the simulator.
//!
//! * [`Driver`] — closed-loop op source: after each completed op the
//!   simulator asks the client's driver for its next op.
//! * [`RandomWorkload`] — the E6/E9 generator: zipfian key choice, tunable
//!   read/write mix, read-before-write probability (blind writes are what
//!   concurrency anomalies feed on), and per-client think time.
//! * [`ScriptDriver`] — fixed op lists (figure replays, targeted tests).
//! * [`zipf`] — the zipfian sampler.

pub mod zipf;

use crate::store::Key;
use crate::testkit::Rng;

/// One client operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Op {
    /// Target key.
    pub key: Key,
    /// GET or PUT.
    pub kind: OpKind,
    /// Think time before the op is issued (µs).
    pub think_us: u64,
}

/// Operation kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Read current siblings + context.
    Get,
    /// Write a payload of the given size.
    Put {
        /// Payload bytes.
        len: u32,
    },
}

/// A closed-loop op source. The simulator calls `next_op` when a client
/// becomes idle; `None` retires the client. The transport-generic
/// harness ([`crate::api::drive_workload`]) consumes the same trait, so
/// one generator drives the DES, the threaded cluster, and live TCP.
pub trait Driver {
    /// Next op for `client`, or `None` when done.
    fn next_op(&mut self, client: usize, now_us: u64, rng: &mut Rng) -> Option<Op>;
}

/// Stable string naming for a workload [`Key`]: the string-keyed client
/// API hashes `key_name(k)` onto the ring, so every transport places a
/// workload key on the same replicas.
pub fn key_name(key: Key) -> String {
    format!("k{key}")
}

/// Parameters for the randomized concurrent workload.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Distinct keys.
    pub keys: u64,
    /// Zipf skew (0.0 = uniform; 0.99 = YCSB-hot).
    pub zipf_theta: f64,
    /// Fraction of ops that are PUTs.
    pub put_fraction: f64,
    /// Probability a PUT is preceded by a GET of the same key (informed
    /// write). Blind writes (the complement) create same-server
    /// concurrency — the §3.2/§5.2 scenario.
    pub read_before_write: f64,
    /// Mean think time between a client's ops (µs, exponential).
    pub mean_think_us: f64,
    /// Ops issued per client before it retires.
    pub ops_per_client: u64,
    /// Payload bytes per PUT.
    pub value_len: u32,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            keys: 100,
            zipf_theta: 0.9,
            put_fraction: 0.5,
            read_before_write: 0.5,
            mean_think_us: 1_000.0,
            ops_per_client: 100,
            value_len: 64,
        }
    }
}

/// Per-client issued-op accounting + pending informed-write chain.
#[derive(Debug, Clone, Default)]
struct ClientCursor {
    issued: u64,
    /// When an informed write is chosen, the GET is issued first and the
    /// PUT to the same key follows immediately after.
    pending_put: Option<Key>,
}

/// The randomized concurrent workload (E6/E9).
#[derive(Debug, Clone)]
pub struct RandomWorkload {
    spec: WorkloadSpec,
    zipf: zipf::Zipf,
    cursors: Vec<ClientCursor>,
}

impl RandomWorkload {
    /// Build for `clients` concurrent clients.
    pub fn new(spec: WorkloadSpec, clients: usize) -> RandomWorkload {
        let zipf = zipf::Zipf::new(spec.keys, spec.zipf_theta);
        RandomWorkload { spec, zipf, cursors: vec![ClientCursor::default(); clients] }
    }

    fn think(&self, rng: &mut Rng) -> u64 {
        rng.exponential(self.spec.mean_think_us).max(1.0) as u64
    }
}

impl Driver for RandomWorkload {
    fn next_op(&mut self, client: usize, _now_us: u64, rng: &mut Rng) -> Option<Op> {
        let think = self.think(rng);
        let spec_len = self.spec.value_len;
        let cur = &mut self.cursors[client];
        // an informed write's PUT half is issued immediately (no think)
        if let Some(key) = cur.pending_put.take() {
            cur.issued += 1;
            return Some(Op { key, kind: OpKind::Put { len: spec_len }, think_us: 1 });
        }
        if cur.issued >= self.spec.ops_per_client {
            return None;
        }
        let key = self.zipf.sample(rng);
        if rng.chance(self.spec.put_fraction) {
            if rng.chance(self.spec.read_before_write) {
                // informed write: GET now, PUT chained next
                cur.issued += 1;
                cur.pending_put = Some(key);
                Some(Op { key, kind: OpKind::Get, think_us: think })
            } else {
                // blind write
                cur.issued += 1;
                Some(Op { key, kind: OpKind::Put { len: spec_len }, think_us: think })
            }
        } else {
            cur.issued += 1;
            Some(Op { key, kind: OpKind::Get, think_us: think })
        }
    }
}

/// One typed set operation ([`crate::api::TypedKvClient`]); elements
/// are named by universe index — [`set_elem`] maps indices onto stable
/// bytes, so every transport mutates the same elements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SetOpKind {
    /// `SADD` element `idx`.
    Add(u64),
    /// `SREM` element `idx`.
    Remove(u64),
    /// `SMEMBERS`.
    Members,
}

/// Stable element bytes for a universe index (the set-workload analogue
/// of [`key_name`]).
pub fn set_elem(idx: u64) -> Vec<u8> {
    format!("e{idx}").into_bytes()
}

/// Parameters for the randomized ORSWOT workload.
#[derive(Debug, Clone)]
pub struct SetWorkloadSpec {
    /// Distinct elements; a *small* universe forces add/remove races on
    /// the same element — the observed-remove semantics under test.
    pub universe: u64,
    /// Fraction of ops that are removes.
    pub remove_fraction: f64,
    /// Fraction of ops that are membership reads.
    pub read_fraction: f64,
    /// Ops issued per client before it retires.
    pub ops_per_client: u64,
}

impl Default for SetWorkloadSpec {
    fn default() -> Self {
        SetWorkloadSpec {
            universe: 16,
            remove_fraction: 0.3,
            read_fraction: 0.1,
            ops_per_client: 50,
        }
    }
}

/// The randomized ORSWOT workload: uniform element choice over a small
/// universe, tunable add/remove/read mix. Consumed by
/// [`crate::api::drive_set_workload`].
#[derive(Debug, Clone)]
pub struct SetWorkload {
    spec: SetWorkloadSpec,
    issued: Vec<u64>,
}

impl SetWorkload {
    /// Build for `clients` concurrent clients.
    pub fn new(spec: SetWorkloadSpec, clients: usize) -> SetWorkload {
        SetWorkload { spec, issued: vec![0; clients] }
    }

    /// Next op for `client`, or `None` when its budget is spent.
    pub fn next_set_op(&mut self, client: usize, rng: &mut Rng) -> Option<SetOpKind> {
        if self.issued[client] >= self.spec.ops_per_client {
            return None;
        }
        self.issued[client] += 1;
        let elem = rng.below(self.spec.universe.max(1));
        if rng.chance(self.spec.read_fraction) {
            Some(SetOpKind::Members)
        } else if rng.chance(self.spec.remove_fraction) {
            Some(SetOpKind::Remove(elem))
        } else {
            Some(SetOpKind::Add(elem))
        }
    }
}

/// Fixed per-client scripts (figure replays and targeted tests).
#[derive(Debug, Clone)]
pub struct ScriptDriver {
    scripts: Vec<std::collections::VecDeque<Op>>,
}

impl ScriptDriver {
    /// One op list per client.
    pub fn new(scripts: Vec<Vec<Op>>) -> ScriptDriver {
        ScriptDriver { scripts: scripts.into_iter().map(Into::into).collect() }
    }
}

impl Driver for ScriptDriver {
    fn next_op(&mut self, client: usize, _now_us: u64, _rng: &mut Rng) -> Option<Op> {
        self.scripts.get_mut(client)?.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_workload_respects_op_budget() {
        let spec = WorkloadSpec { ops_per_client: 10, ..Default::default() };
        let mut w = RandomWorkload::new(spec, 2);
        let mut rng = Rng::new(1);
        let mut count = 0;
        while w.next_op(0, 0, &mut rng).is_some() {
            count += 1;
            assert!(count < 50, "runaway");
        }
        // informed writes chain one extra PUT after the budgeted GET
        assert!((10..=20).contains(&count), "count={count}");
        // client 1 untouched
        assert!(w.next_op(1, 0, &mut rng).is_some());
    }

    #[test]
    fn informed_write_chains_get_then_put() {
        let spec = WorkloadSpec {
            put_fraction: 1.0,
            read_before_write: 1.0,
            ops_per_client: 3,
            ..Default::default()
        };
        let mut w = RandomWorkload::new(spec, 1);
        let mut rng = Rng::new(2);
        let first = w.next_op(0, 0, &mut rng).unwrap();
        assert_eq!(first.kind, OpKind::Get);
        let second = w.next_op(0, 0, &mut rng).unwrap();
        assert!(matches!(second.kind, OpKind::Put { .. }));
        assert_eq!(second.key, first.key, "PUT follows its GET's key");
    }

    #[test]
    fn blind_write_mode_issues_puts_directly() {
        let spec = WorkloadSpec {
            put_fraction: 1.0,
            read_before_write: 0.0,
            ops_per_client: 5,
            ..Default::default()
        };
        let mut w = RandomWorkload::new(spec, 1);
        let mut rng = Rng::new(3);
        for _ in 0..5 {
            let op = w.next_op(0, 0, &mut rng).unwrap();
            assert!(matches!(op.kind, OpKind::Put { .. }));
        }
        assert!(w.next_op(0, 0, &mut rng).is_none());
    }

    #[test]
    fn script_driver_plays_in_order() {
        let ops = vec![
            Op { key: 1, kind: OpKind::Get, think_us: 5 },
            Op { key: 1, kind: OpKind::Put { len: 8 }, think_us: 5 },
        ];
        let mut d = ScriptDriver::new(vec![ops.clone()]);
        let mut rng = Rng::new(4);
        assert_eq!(d.next_op(0, 0, &mut rng), Some(ops[0].clone()));
        assert_eq!(d.next_op(0, 0, &mut rng), Some(ops[1].clone()));
        assert_eq!(d.next_op(0, 0, &mut rng), None);
    }

    #[test]
    fn set_workload_respects_budget_and_universe() {
        let spec = SetWorkloadSpec { universe: 8, ops_per_client: 20, ..Default::default() };
        let mut w = SetWorkload::new(spec, 2);
        let mut rng = Rng::new(7);
        let mut count = 0;
        while let Some(op) = w.next_set_op(0, &mut rng) {
            if let SetOpKind::Add(e) | SetOpKind::Remove(e) = op {
                assert!(e < 8, "element {e} outside the universe");
            }
            count += 1;
            assert!(count <= 20, "runaway");
        }
        assert_eq!(count, 20);
        // client 1 untouched
        assert!(w.next_set_op(1, &mut rng).is_some());
    }

    #[test]
    fn set_workload_mix_covers_all_op_kinds() {
        let spec = SetWorkloadSpec {
            universe: 4,
            remove_fraction: 0.4,
            read_fraction: 0.2,
            ops_per_client: 200,
        };
        let mut w = SetWorkload::new(spec, 1);
        let mut rng = Rng::new(9);
        let (mut adds, mut removes, mut reads) = (0, 0, 0);
        while let Some(op) = w.next_set_op(0, &mut rng) {
            match op {
                SetOpKind::Add(_) => adds += 1,
                SetOpKind::Remove(_) => removes += 1,
                SetOpKind::Members => reads += 1,
            }
        }
        assert!(adds > 0 && removes > 0 && reads > 0, "{adds}/{removes}/{reads}");
    }

    #[test]
    fn set_elems_are_stable_and_distinct() {
        assert_eq!(set_elem(3), b"e3".to_vec());
        assert_ne!(set_elem(1), set_elem(2));
    }

    #[test]
    fn keys_stay_in_range() {
        let spec = WorkloadSpec { keys: 10, ops_per_client: 50, ..Default::default() };
        let mut w = RandomWorkload::new(spec, 1);
        let mut rng = Rng::new(5);
        while let Some(op) = w.next_op(0, 0, &mut rng) {
            assert!(op.key < 10);
        }
    }
}
