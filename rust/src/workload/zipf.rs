//! Zipfian key sampler (YCSB-style), rejection-free via the standard
//! Gray et al. "quick and portable" incremental method.

use crate::testkit::Rng;

/// Zipf distribution over `0..n` with skew `theta` (0 = uniform).
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2: f64,
}

impl Zipf {
    /// Precompute constants for `n` items with skew `theta`.
    pub fn new(n: u64, theta: f64) -> Zipf {
        assert!(n > 0, "zipf needs n > 0");
        if theta <= 0.0 {
            return Zipf { n, theta: 0.0, alpha: 0.0, zetan: 0.0, eta: 0.0, zeta2: 0.0 };
        }
        let zetan = zeta(n, theta);
        let zeta2 = zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Zipf { n, theta, alpha, zetan, eta, zeta2: 0.0 * zeta2 }
    }

    /// Draw one key in `0..n`.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let _ = self.zeta2;
        if self.theta <= 0.0 {
            return rng.below(self.n);
        }
        let u = rng.f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let k = (self.n as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64;
        k.min(self.n - 1)
    }
}

fn zeta(n: u64, theta: f64) -> f64 {
    // exact up to a cap, then the Euler–Maclaurin tail approximation;
    // workloads here use n small enough for the exact sum.
    let cap = n.min(1_000_000);
    let mut sum = 0.0;
    for i in 1..=cap {
        sum += 1.0 / (i as f64).powf(theta);
    }
    if n > cap {
        // integral tail
        sum += ((n as f64).powf(1.0 - theta) - (cap as f64).powf(1.0 - theta)) / (1.0 - theta);
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_when_theta_zero() {
        let z = Zipf::new(10, 0.0);
        let mut rng = Rng::new(1);
        let mut counts = [0u64; 10];
        for _ in 0..10_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn skewed_prefers_low_keys() {
        let z = Zipf::new(1000, 0.99);
        let mut rng = Rng::new(2);
        let mut head = 0;
        let total = 20_000;
        for _ in 0..total {
            if z.sample(&mut rng) < 10 {
                head += 1;
            }
        }
        // with theta=.99 the top-10 keys take a large share
        assert!(head > total / 4, "head={head}");
    }

    #[test]
    fn samples_in_range() {
        for theta in [0.0, 0.5, 0.9, 0.99] {
            let z = Zipf::new(7, theta);
            let mut rng = Rng::new(3);
            for _ in 0..2000 {
                assert!(z.sample(&mut rng) < 7);
            }
        }
    }

    #[test]
    fn single_item_always_zero() {
        let z = Zipf::new(1, 0.9);
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut rng), 0);
        }
    }
}
