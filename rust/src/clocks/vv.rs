//! Version vectors (§3.2): per-actor contiguous event-range summaries.
//!
//! A version vector `{(a,2),(b,1)}` summarizes the causal history
//! `{a1,a2,b1}`. Comparison is pointwise; the join is the pointwise max.
//! Kept as a sorted association list — replica counts per key are small
//! (the paper's lowest order of magnitude), so a flat vec beats tree maps
//! on both space and compare cost.

use std::fmt;

use super::{Actor, CausalHistory, ClockOrd, Event, LogicalClock};

/// A version vector: sorted `(actor, max-seq)` pairs, seq >= 1.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct VersionVector {
    entries: Vec<(Actor, u64)>,
}

impl VersionVector {
    /// The empty vector (bottom of the lattice).
    pub fn new() -> VersionVector {
        VersionVector::default()
    }

    /// Build from unsorted pairs; zero counters are dropped.
    pub fn from_pairs<I: IntoIterator<Item = (Actor, u64)>>(pairs: I) -> VersionVector {
        let mut entries: Vec<(Actor, u64)> =
            pairs.into_iter().filter(|&(_, n)| n > 0).collect();
        entries.sort_unstable_by_key(|&(a, _)| a);
        entries.dedup_by(|b, a| {
            if a.0 == b.0 {
                a.1 = a.1.max(b.1);
                true
            } else {
                false
            }
        });
        VersionVector { entries }
    }

    /// Number of non-zero entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no entries are present.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Counter for `actor` (0 when absent).
    pub fn get(&self, actor: Actor) -> u64 {
        match self.entries.binary_search_by_key(&actor, |&(a, _)| a) {
            Ok(i) => self.entries[i].1,
            Err(_) => 0,
        }
    }

    /// Set `actor`'s counter (removing the entry when 0).
    pub fn set(&mut self, actor: Actor, seq: u64) {
        match self.entries.binary_search_by_key(&actor, |&(a, _)| a) {
            Ok(i) => {
                if seq == 0 {
                    self.entries.remove(i);
                } else {
                    self.entries[i].1 = seq;
                }
            }
            Err(i) => {
                if seq > 0 {
                    self.entries.insert(i, (actor, seq));
                }
            }
        }
    }

    /// Bump `actor`'s counter by one and return the new value.
    pub fn increment(&mut self, actor: Actor) -> u64 {
        let next = self.get(actor) + 1;
        self.set(actor, next);
        next
    }

    /// Pointwise max, in place (the lattice join).
    pub fn join_from(&mut self, other: &VersionVector) {
        for &(a, n) in &other.entries {
            if self.get(a) < n {
                self.set(a, n);
            }
        }
    }

    /// Pointwise max, by value.
    pub fn join(&self, other: &VersionVector) -> VersionVector {
        let mut out = self.clone();
        out.join_from(other);
        out
    }

    /// `self <= other` pointwise.
    pub fn dominated_by(&self, other: &VersionVector) -> bool {
        self.entries.iter().all(|&(a, n)| n <= other.get(a))
    }

    /// Iterate `(actor, seq)` pairs in actor order.
    pub fn iter(&self) -> impl Iterator<Item = (Actor, u64)> + '_ {
        self.entries.iter().copied()
    }

    /// The summarized causal history (exact for version vectors).
    pub fn history(&self) -> CausalHistory {
        CausalHistory::from_events(
            self.entries
                .iter()
                .flat_map(|&(a, n)| (1..=n).map(move |s| Event::new(a, s))),
        )
    }
}

impl LogicalClock for VersionVector {
    fn compare(&self, other: &VersionVector) -> ClockOrd {
        ClockOrd::from_leq_geq(self.dominated_by(other), other.dominated_by(self))
    }

    fn encoded_size(&self) -> usize {
        super::encoding::varint_len(self.len() as u64)
            + self
                .iter()
                .map(|(a, n)| {
                    super::encoding::varint_len(a.0 as u64) + super::encoding::varint_len(n)
                })
                .sum::<usize>()
    }
}

impl fmt::Display for VersionVector {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (i, (a, n)) in self.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "({a},{n})")?;
        }
        write!(f, "}}")
    }
}

/// Shorthand constructor for tests/figures: `vv(&[(a, 2), (b, 1)])`.
pub fn vv(pairs: &[(Actor, u64)]) -> VersionVector {
    VersionVector::from_pairs(pairs.iter().copied())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::{forall, from_fn, Config};
    use crate::testkit::Rng;

    fn a() -> Actor {
        Actor::server(0)
    }
    fn b() -> Actor {
        Actor::server(1)
    }
    fn c() -> Actor {
        Actor::server(2)
    }

    #[test]
    fn summarizes_history_exactly() {
        // §3.2's example: {a1,a2,b1,b2,c1} == {(a,2),(b,2),(c,1)}
        let v = vv(&[(a(), 2), (b(), 2), (c(), 1)]);
        let h = crate::clocks::causal_history::hist(&[
            (a(), 1),
            (a(), 2),
            (b(), 1),
            (b(), 2),
            (c(), 1),
        ]);
        assert_eq!(v.history(), h);
    }

    #[test]
    fn figure3_comparisons() {
        // y={(a,2)} vs w={(b,2)}: concurrent (correctly detected, §3.2)
        let y = vv(&[(a(), 2)]);
        let w = vv(&[(b(), 2)]);
        assert_eq!(y.compare(&w), ClockOrd::Concurrent);
        // but v={(b,1)} vs w={(b,2)}: v falsely dominated (the §3.2 anomaly)
        let v = vv(&[(b(), 1)]);
        assert_eq!(v.compare(&w), ClockOrd::Less);
    }

    #[test]
    fn get_set_increment() {
        let mut v = VersionVector::new();
        assert_eq!(v.get(a()), 0);
        assert_eq!(v.increment(a()), 1);
        assert_eq!(v.increment(a()), 2);
        v.set(b(), 7);
        assert_eq!(v.get(b()), 7);
        v.set(b(), 0);
        assert_eq!(v.get(b()), 0);
        assert_eq!(v.len(), 1);
    }

    #[test]
    fn from_pairs_dedups_and_sorts() {
        let v = VersionVector::from_pairs(vec![(b(), 1), (a(), 3), (b(), 5), (c(), 0)]);
        assert_eq!(v.get(a()), 3);
        assert_eq!(v.get(b()), 5);
        assert_eq!(v.len(), 2);
        let actors: Vec<Actor> = v.iter().map(|(x, _)| x).collect();
        assert_eq!(actors, vec![a(), b()]);
    }

    #[test]
    fn join_is_lub() {
        let x = vv(&[(a(), 2), (b(), 1)]);
        let y = vv(&[(a(), 1), (c(), 4)]);
        let j = x.join(&y);
        assert_eq!(j, vv(&[(a(), 2), (b(), 1), (c(), 4)]));
        assert!(x.dominated_by(&j) && y.dominated_by(&j));
    }

    fn arb_vv(rng: &mut Rng, size: usize) -> VersionVector {
        let actors = 1 + size / 25;
        VersionVector::from_pairs(
            (0..actors as u32).map(|i| (Actor::server(i), rng.below(6))),
        )
    }

    #[test]
    fn prop_compare_agrees_with_history_inclusion() {
        forall(
            &Config::default().cases(200),
            from_fn(|rng, size| (arb_vv(rng, size), arb_vv(rng, size))),
            |(x, y)| x.compare(y) == x.history().compare(&y.history()),
        );
    }

    #[test]
    fn prop_join_laws() {
        forall(
            &Config::default().cases(150),
            from_fn(|rng, size| (arb_vv(rng, size), arb_vv(rng, size))),
            |(x, y)| {
                let xy = x.join(y);
                xy == y.join(x) && x.join(x) == *x && x.dominated_by(&xy)
            },
        );
    }

    #[test]
    fn encoded_size_linear_in_entries() {
        let small = vv(&[(a(), 1)]);
        let big = VersionVector::from_pairs((0..64u32).map(|i| (Actor::server(i), 3)));
        assert!(big.encoded_size() > 32 * small.encoded_size() / 2);
    }

    #[test]
    fn display_notation() {
        assert_eq!(vv(&[(a(), 2), (b(), 1)]).to_string(), "{(a,2),(b,1)}");
    }
}
