//! Wire codecs for clocks (LEB128 varints), used both by the TCP server
//! protocol and by the metadata-size experiments (DESIGN.md E7).
//!
//! Every clock type gets `encode`/`decode` round-trips here; the
//! `encoded_size` methods on the clock types are defined to match what
//! these codecs emit (asserted by tests).

use super::{Actor, CausalHistory, ClockOrd, Dvv, Event, LamportClock, LogicalClock, RtClock, VersionVector};
use crate::error::{Error, Result};

/// Length of `value` as a LEB128 varint.
pub fn varint_len(value: u64) -> usize {
    let bits = 64 - value.leading_zeros().max(0) as usize;
    std::cmp::max(1, bits.div_ceil(7))
}

/// Append a LEB128 varint.
pub fn put_varint(buf: &mut Vec<u8>, mut value: u64) {
    loop {
        let byte = (value & 0x7f) as u8;
        value >>= 7;
        if value == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Read a LEB128 varint, advancing `pos`.
pub fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut out: u64 = 0;
    let mut shift = 0u32;
    loop {
        let byte = *buf
            .get(*pos)
            .ok_or_else(|| Error::Codec("varint: unexpected end".into()))?;
        *pos += 1;
        out |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return Ok(out);
        }
        shift += 7;
        if shift >= 64 {
            return Err(Error::Codec("varint: overflow".into()));
        }
    }
}

/// Append a signed value as a zigzag-mapped varint (small magnitudes of
/// either sign stay small on the wire) — used by the counter protocol
/// replies ([`crate::server::protocol`]).
pub fn put_zigzag(buf: &mut Vec<u8>, value: i64) {
    put_varint(buf, ((value << 1) ^ (value >> 63)) as u64);
}

/// Read a zigzag-mapped signed varint (see [`put_zigzag`]).
pub fn get_zigzag(buf: &[u8], pos: &mut usize) -> Result<i64> {
    let raw = get_varint(buf, pos)?;
    Ok(((raw >> 1) as i64) ^ -((raw & 1) as i64))
}

/// Bounds-checked slice read, advancing `pos`: decoders of remote input
/// must error on truncation, never index past the buffer.
pub fn get_bytes<'a>(buf: &'a [u8], pos: &mut usize, len: usize) -> Result<&'a [u8]> {
    let end = pos
        .checked_add(len)
        .filter(|&e| e <= buf.len())
        .ok_or_else(|| Error::Codec("byte field truncated".into()))?;
    let out = &buf[*pos..end];
    *pos = end;
    Ok(out)
}

/// Reject encodings with bytes past the last decoded field.
pub fn expect_end(buf: &[u8], pos: usize) -> Result<()> {
    if pos == buf.len() {
        Ok(())
    } else {
        Err(Error::Codec(format!("{} trailing bytes", buf.len() - pos)))
    }
}

/// Encode a version vector.
pub fn encode_vv(vv: &VersionVector, buf: &mut Vec<u8>) {
    put_varint(buf, vv.len() as u64);
    for (a, n) in vv.iter() {
        put_varint(buf, a.0 as u64);
        put_varint(buf, n);
    }
}

/// Decode a version vector.
pub fn decode_vv(buf: &[u8], pos: &mut usize) -> Result<VersionVector> {
    let count = get_varint(buf, pos)?;
    // cap the pre-allocation by what the remaining bytes could possibly
    // hold (2 bytes minimum per entry): a hostile count must run into
    // the truncation error, never pick an allocation size
    let cap = (count as usize).min(buf.len().saturating_sub(*pos) / 2);
    let mut pairs = Vec::with_capacity(cap);
    for _ in 0..count {
        let a = get_varint(buf, pos)? as u32;
        let n = get_varint(buf, pos)?;
        pairs.push((Actor(a), n));
    }
    Ok(VersionVector::from_pairs(pairs))
}

/// Encode a dotted version vector.
pub fn encode_dvv(d: &Dvv, buf: &mut Vec<u8>) {
    encode_vv(&d.vv, buf);
    match d.dot {
        None => buf.push(0),
        Some((a, n)) => {
            buf.push(1);
            put_varint(buf, a.0 as u64);
            put_varint(buf, n);
        }
    }
}

/// Decode a dotted version vector.
pub fn decode_dvv(buf: &[u8], pos: &mut usize) -> Result<Dvv> {
    let vv = decode_vv(buf, pos)?;
    let flag = *buf
        .get(*pos)
        .ok_or_else(|| Error::Codec("dvv: missing dot flag".into()))?;
    *pos += 1;
    let dot = match flag {
        0 => None,
        1 => {
            let a = get_varint(buf, pos)? as u32;
            let n = get_varint(buf, pos)?;
            Some((Actor(a), n))
        }
        other => return Err(Error::Codec(format!("dvv: bad dot flag {other}"))),
    };
    Ok(Dvv { vv, dot })
}

/// Encode a causal history (explicit event set).
pub fn encode_history(h: &CausalHistory, buf: &mut Vec<u8>) {
    put_varint(buf, h.len() as u64);
    for e in h.iter() {
        put_varint(buf, e.actor.0 as u64);
        put_varint(buf, e.seq);
    }
}

/// Decode a causal history.
pub fn decode_history(buf: &[u8], pos: &mut usize) -> Result<CausalHistory> {
    let count = get_varint(buf, pos)?;
    let mut h = CausalHistory::new();
    for _ in 0..count {
        let a = get_varint(buf, pos)? as u32;
        let s = get_varint(buf, pos)?;
        h.insert(Event::new(Actor(a), s));
    }
    Ok(h)
}

/// Encode a physical timestamp clock.
pub fn encode_rt(c: &RtClock, buf: &mut Vec<u8>) {
    put_varint(buf, c.micros);
    put_varint(buf, c.actor.0 as u64);
}

/// Decode a physical timestamp clock.
pub fn decode_rt(buf: &[u8], pos: &mut usize) -> Result<RtClock> {
    let micros = get_varint(buf, pos)?;
    let actor = Actor(get_varint(buf, pos)? as u32);
    Ok(RtClock { micros, actor })
}

/// Encode a Lamport clock.
pub fn encode_lamport(c: &LamportClock, buf: &mut Vec<u8>) {
    put_varint(buf, c.counter);
    put_varint(buf, c.actor.0 as u64);
}

/// Decode a Lamport clock.
pub fn decode_lamport(buf: &[u8], pos: &mut usize) -> Result<LamportClock> {
    let counter = get_varint(buf, pos)?;
    let actor = Actor(get_varint(buf, pos)? as u32);
    Ok(LamportClock { counter, actor })
}

/// Cross-mechanism size probe used by the metadata benches: encodes the
/// clock and reports the byte count.
pub fn measured_size<C: LogicalClock>(clock: &C) -> usize {
    clock.encoded_size()
}

/// Sanity helper for tests: equal clocks must encode identically.
pub fn codec_stable(a: &Dvv, b: &Dvv) -> bool {
    if a.compare(b) != ClockOrd::Equal {
        return true;
    }
    let (mut ba, mut bb) = (Vec::new(), Vec::new());
    encode_dvv(a, &mut ba);
    encode_dvv(b, &mut bb);
    // equal *histories* may differ in representation (dot vs folded dot);
    // after compaction the encodings must match
    let (mut ca, mut cb) = (a.clone(), b.clone());
    ca.compact();
    cb.compact();
    let (mut ba2, mut bb2) = (Vec::new(), Vec::new());
    encode_dvv(&ca, &mut ba2);
    encode_dvv(&cb, &mut bb2);
    ba2 == bb2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::causal_history::hist;
    use crate::clocks::dvv::dvv;
    use crate::clocks::vv::vv;
    use crate::testkit::prop::{forall, from_fn, Config};

    fn a() -> Actor {
        Actor::server(0)
    }
    fn b() -> Actor {
        Actor::server(1)
    }

    #[test]
    fn varint_roundtrip_boundaries() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            assert_eq!(buf.len(), varint_len(v), "len mismatch for {v}");
            let mut pos = 0;
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn zigzag_roundtrip_boundaries() {
        for v in [0i64, 1, -1, 63, -64, 64, -65, i64::MAX, i64::MIN] {
            let mut buf = Vec::new();
            put_zigzag(&mut buf, v);
            let mut pos = 0;
            assert_eq!(get_zigzag(&buf, &mut pos).unwrap(), v, "value {v}");
            assert_eq!(pos, buf.len());
        }
        // small magnitudes of either sign stay one byte
        for v in [-64i64, 63] {
            let mut buf = Vec::new();
            put_zigzag(&mut buf, v);
            assert_eq!(buf.len(), 1, "value {v}");
        }
    }

    #[test]
    fn get_bytes_is_bounds_checked() {
        let buf = [1u8, 2, 3];
        let mut pos = 0;
        assert_eq!(get_bytes(&buf, &mut pos, 2).unwrap(), &[1, 2]);
        assert!(get_bytes(&buf, &mut pos, 2).is_err(), "past the end");
        assert_eq!(pos, 2, "pos untouched on failure");
        assert!(get_bytes(&buf, &mut pos, usize::MAX).is_err(), "overflow-safe");
        assert!(expect_end(&buf, 2).is_err());
        assert!(expect_end(&buf, 3).is_ok());
    }

    #[test]
    fn varint_rejects_truncation() {
        let mut buf = Vec::new();
        put_varint(&mut buf, 300);
        buf.truncate(1);
        let mut pos = 0;
        assert!(get_varint(&buf, &mut pos).is_err());
    }

    #[test]
    fn vv_roundtrip_and_size() {
        let v = vv(&[(a(), 2), (b(), 70000)]);
        let mut buf = Vec::new();
        encode_vv(&v, &mut buf);
        assert_eq!(buf.len(), v.encoded_size());
        let mut pos = 0;
        assert_eq!(decode_vv(&buf, &mut pos).unwrap(), v);
    }

    #[test]
    fn dvv_roundtrip_and_size() {
        for d in [
            dvv(&[], None),
            dvv(&[], Some((b(), 2))),
            dvv(&[(a(), 2), (b(), 1)], Some((a(), 9))),
        ] {
            let mut buf = Vec::new();
            encode_dvv(&d, &mut buf);
            assert_eq!(buf.len(), d.encoded_size(), "{d}");
            let mut pos = 0;
            assert_eq!(decode_dvv(&buf, &mut pos).unwrap(), d);
        }
    }

    #[test]
    fn history_roundtrip() {
        let h = hist(&[(a(), 1), (a(), 2), (b(), 1)]);
        let mut buf = Vec::new();
        encode_history(&h, &mut buf);
        assert_eq!(buf.len(), h.encoded_size());
        let mut pos = 0;
        assert_eq!(decode_history(&buf, &mut pos).unwrap(), h);
    }

    #[test]
    fn rt_and_lamport_roundtrip() {
        let r = RtClock::new(123456, Actor::client(3));
        let mut buf = Vec::new();
        encode_rt(&r, &mut buf);
        assert_eq!(buf.len(), r.encoded_size());
        let mut pos = 0;
        assert_eq!(decode_rt(&buf, &mut pos).unwrap(), r);

        let l = LamportClock::new(42, Actor::server(1));
        let mut buf = Vec::new();
        encode_lamport(&l, &mut buf);
        assert_eq!(buf.len(), l.encoded_size());
        let mut pos = 0;
        assert_eq!(decode_lamport(&buf, &mut pos).unwrap(), l);
    }

    #[test]
    fn concatenated_clocks_decode_in_sequence() {
        let v = vv(&[(a(), 5)]);
        let d = dvv(&[(b(), 1)], Some((a(), 2)));
        let mut buf = Vec::new();
        encode_vv(&v, &mut buf);
        encode_dvv(&d, &mut buf);
        let mut pos = 0;
        assert_eq!(decode_vv(&buf, &mut pos).unwrap(), v);
        assert_eq!(decode_dvv(&buf, &mut pos).unwrap(), d);
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn prop_dvv_roundtrip() {
        forall(
            &Config::default().cases(200),
            from_fn(|rng, _| {
                let vvp = VersionVector::from_pairs(
                    (0..4u32).map(|i| (Actor::server(i), rng.below(100))),
                );
                let dot = if rng.chance(0.5) {
                    let r = Actor::server(rng.below(4) as u32);
                    Some((r, vvp.get(r) + 1 + rng.below(5)))
                } else {
                    None
                };
                Dvv { vv: vvp, dot }
            }),
            |d| {
                let mut buf = Vec::new();
                encode_dvv(d, &mut buf);
                let mut pos = 0;
                decode_dvv(&buf, &mut pos).unwrap() == *d && buf.len() == d.encoded_size()
            },
        );
    }
}
