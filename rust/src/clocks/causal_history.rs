//! Causal histories: explicit sets of update-event identifiers (§3).
//!
//! "Causal histories are simply described by sets of unique update event
//! identifiers. The partial order of causality can be precisely tracked by
//! comparing these sets by set inclusion." They are the paper's semantic
//! ground truth — every other mechanism is evaluated against them — but
//! scale linearly with the number of updates, so real systems compress
//! them (version vectors, dotted version vectors).

use std::collections::BTreeSet;
use std::fmt;

use super::{Actor, ClockOrd, Event, LogicalClock};

/// An explicit causal history: a set of events such as `{a1, a2, b1}`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CausalHistory {
    events: BTreeSet<Event>,
}

impl CausalHistory {
    /// The empty history `{}`.
    pub fn new() -> CausalHistory {
        CausalHistory::default()
    }

    /// Build from a list of events.
    pub fn from_events<I: IntoIterator<Item = Event>>(events: I) -> CausalHistory {
        CausalHistory { events: events.into_iter().collect() }
    }

    /// Number of events recorded.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True for the empty history.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Membership test.
    pub fn contains(&self, e: &Event) -> bool {
        self.events.contains(e)
    }

    /// Add a single event.
    pub fn insert(&mut self, e: Event) {
        self.events.insert(e);
    }

    /// Union with another history (the join on the event-set lattice).
    pub fn union(&self, other: &CausalHistory) -> CausalHistory {
        CausalHistory { events: self.events.union(&other.events).copied().collect() }
    }

    /// In-place union.
    pub fn merge_from(&mut self, other: &CausalHistory) {
        self.events.extend(other.events.iter().copied());
    }

    /// Subset test: `self ⊆ other`.
    pub fn is_subset(&self, other: &CausalHistory) -> bool {
        self.events.is_subset(&other.events)
    }

    /// Largest sequence number recorded for `actor` (0 when absent) —
    /// the `⌈·⌉_r` function of §5.3 evaluated on explicit sets.
    pub fn max_seq(&self, actor: Actor) -> u64 {
        self.events
            .iter()
            .filter(|e| e.actor == actor)
            .map(|e| e.seq)
            .max()
            .unwrap_or(0)
    }

    /// Iterate events in order.
    pub fn iter(&self) -> impl Iterator<Item = &Event> {
        self.events.iter()
    }

    /// Actors appearing in the history.
    pub fn actors(&self) -> BTreeSet<Actor> {
        self.events.iter().map(|e| e.actor).collect()
    }

    /// Is the history a *downset* (§5.4): for each actor, all events from
    /// 1 up to its maximum are present (no holes)?
    pub fn is_downset(&self) -> bool {
        self.actors().iter().all(|&a| {
            let max = self.max_seq(a);
            (1..=max).all(|s| self.contains(&Event::new(a, s)))
        })
    }
}

impl LogicalClock for CausalHistory {
    fn compare(&self, other: &CausalHistory) -> ClockOrd {
        ClockOrd::from_leq_geq(self.is_subset(other), other.is_subset(self))
    }

    fn encoded_size(&self) -> usize {
        encoding_size(self)
    }
}

fn encoding_size(h: &CausalHistory) -> usize {
    // count prefix + (actor varint, seq varint) per event
    super::encoding::varint_len(h.len() as u64)
        + h.iter()
            .map(|e| {
                super::encoding::varint_len(e.actor.0 as u64)
                    + super::encoding::varint_len(e.seq)
            })
            .sum::<usize>()
}

impl fmt::Display for CausalHistory {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{e}")?;
        }
        write!(f, "}}")
    }
}

/// Convenience macro-free constructor used heavily in figure replays:
/// `hist(&[("a", 1), ("a", 2), ("b", 1)])`.
pub fn hist(events: &[(Actor, u64)]) -> CausalHistory {
    CausalHistory::from_events(events.iter().map(|&(a, s)| Event::new(a, s)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a() -> Actor {
        Actor::server(0)
    }
    fn b() -> Actor {
        Actor::server(1)
    }

    #[test]
    fn figure1_relations() {
        // Fig. 1 end state: y={a1,a2} on Ra; v={b1}, w={b2} on Rb.
        let y = hist(&[(a(), 1), (a(), 2)]);
        let v = hist(&[(b(), 1)]);
        let w = hist(&[(b(), 2)]);
        assert_eq!(y.compare(&v), ClockOrd::Concurrent);
        assert_eq!(y.compare(&w), ClockOrd::Concurrent);
        assert_eq!(v.compare(&w), ClockOrd::Concurrent);
        // x={a1} was overwritten by y: {a1} ⊂ {a1,a2}
        let x = hist(&[(a(), 1)]);
        assert_eq!(x.compare(&y), ClockOrd::Less);
        assert_eq!(y.compare(&x), ClockOrd::Greater);
    }

    #[test]
    fn empty_history_is_bottom() {
        let empty = CausalHistory::new();
        let any = hist(&[(a(), 1)]);
        assert_eq!(empty.compare(&any), ClockOrd::Less);
        assert_eq!(empty.compare(&empty), ClockOrd::Equal);
        assert!(empty.is_downset());
    }

    #[test]
    fn union_is_join() {
        let x = hist(&[(a(), 1)]);
        let y = hist(&[(b(), 1)]);
        let u = x.union(&y);
        assert_eq!(x.compare(&u), ClockOrd::Less);
        assert_eq!(y.compare(&u), ClockOrd::Less);
        assert_eq!(u.len(), 2);
    }

    #[test]
    fn max_seq_and_downset() {
        let h = hist(&[(a(), 1), (a(), 2), (b(), 1)]);
        assert_eq!(h.max_seq(a()), 2);
        assert_eq!(h.max_seq(b()), 1);
        assert_eq!(h.max_seq(Actor::server(9)), 0);
        assert!(h.is_downset());
        let holed = hist(&[(a(), 1), (a(), 3)]);
        assert!(!holed.is_downset());
    }

    #[test]
    fn display_matches_paper_notation() {
        let h = hist(&[(a(), 1), (b(), 2)]);
        assert_eq!(h.to_string(), "{a1,b2}");
    }

    #[test]
    fn merge_from_accumulates() {
        let mut h = hist(&[(a(), 1)]);
        h.merge_from(&hist(&[(b(), 1)]));
        h.insert(Event::new(a(), 2));
        assert_eq!(h.len(), 3);
        assert!(h.contains(&Event::new(b(), 1)));
    }

    #[test]
    fn encoded_size_grows_with_updates() {
        // the paper's §3 scalability complaint: linear in #updates
        let small = hist(&[(a(), 1)]);
        let big = CausalHistory::from_events((1..=100).map(|s| Event::new(a(), s)));
        assert!(big.encoded_size() > 50 * small.encoded_size() / 2);
    }
}
