//! Hybrid logical clocks for cross-DC ordering (geo-replication).
//!
//! A plain physical timestamp breaks as soon as a node's clock jumps
//! backward (NTP step, VM migration) — exactly the anomaly GentleRain+
//! hardens against. An HLC keeps a timestamp that *tracks* physical time
//! when clocks behave (the `l` component stays within the largest
//! physical time the node has seen) yet stays **strictly monotone** per
//! node under local events, sends, and receives even when the injected
//! physical clock runs backward: the logical counter `c` breaks ties
//! whenever `l` cannot advance.
//!
//! Update rules (Kulkarni et al., adopted by GentleRain+/Okapi for
//! cross-DC stabilization):
//!
//! * local/send at physical time `pt`:
//!   `l' = max(l, pt)`; `c' = c + 1` if `l' == l` else `0`.
//! * receive a remote timestamp `m` at physical time `pt`:
//!   `l' = max(l, m.l, pt)`; `c'` is `max(c, m.c) + 1` when `l'` ties
//!   both, `c + 1` when it ties only ours, `m.c + 1` when it ties only
//!   the remote's, and `0` when fresh physical time won outright.
//!
//! The drift bound follows directly: `l` never exceeds the largest
//! physical time any merged-in event carried, so a bounded clock skew
//! gives a bounded `l − pt` (asserted by the geo property tests).
//!
//! Timestamps pack into one `u64` — 48 bits of microseconds (good past
//! year 8900) over 16 bits of counter — so the cross-DC shipper sends a
//! single ordered word per batch and `STATS` can report it.

use std::fmt;

use super::encoding::{get_varint, put_varint, varint_len};
use crate::error::Result;

/// Bits reserved for the logical counter in the packed form.
pub const COUNTER_BITS: u32 = 16;

/// One hybrid timestamp: physical-dominant `l` (µs) plus tie-breaking
/// logical counter `c`. The derived lexicographic `Ord` on `(l, c)` *is*
/// the HLC order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct HlcTimestamp {
    /// Physical-time component (µs): the largest physical clock reading
    /// this timestamp's causal past has seen.
    pub l: u64,
    /// Logical counter: events that share one `l` are ordered by `c`.
    pub c: u64,
}

impl HlcTimestamp {
    /// Construct from components.
    pub fn new(l: u64, c: u64) -> HlcTimestamp {
        HlcTimestamp { l, c }
    }

    /// Pack into one word: `l` in the high 48 bits, `c` in the low 16.
    /// Packing preserves order whenever both components fit; an
    /// overflowing counter saturates rather than carrying into `l`
    /// (2^16 same-microsecond events would need a stalled clock *and* a
    /// pathological event rate).
    pub fn pack(self) -> u64 {
        let l = self.l & ((1 << (64 - COUNTER_BITS)) - 1);
        let c = self.c.min((1 << COUNTER_BITS) - 1);
        (l << COUNTER_BITS) | c
    }

    /// Unpack a [`pack`](HlcTimestamp::pack)ed word.
    pub fn unpack(word: u64) -> HlcTimestamp {
        HlcTimestamp {
            l: word >> COUNTER_BITS,
            c: word & ((1 << COUNTER_BITS) - 1),
        }
    }

    /// Encoded wire size (two varints).
    pub fn encoded_size(&self) -> usize {
        varint_len(self.l) + varint_len(self.c)
    }
}

impl fmt::Display for HlcTimestamp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}+{}", self.l, self.c)
    }
}

/// Encode an [`HlcTimestamp`] as two varints (wire form for the ship
/// opcodes and the geo STATS fields).
pub fn encode_hlc(ts: &HlcTimestamp, buf: &mut Vec<u8>) {
    put_varint(buf, ts.l);
    put_varint(buf, ts.c);
}

/// Decode an [`HlcTimestamp`], advancing `pos`.
pub fn decode_hlc(buf: &[u8], pos: &mut usize) -> Result<HlcTimestamp> {
    let l = get_varint(buf, pos)?;
    let c = get_varint(buf, pos)?;
    Ok(HlcTimestamp { l, c })
}

/// One node's hybrid logical clock: the last timestamp issued, advanced
/// by [`now`](Hlc::now) on local/send events and [`recv`](Hlc::recv) on
/// message receipt. Both return a timestamp **strictly greater** than
/// every timestamp this clock issued before, regardless of what the
/// injected physical clock does.
#[derive(Debug, Clone, Default)]
pub struct Hlc {
    last: HlcTimestamp,
}

impl Hlc {
    /// Fresh clock at the zero timestamp.
    pub fn new() -> Hlc {
        Hlc::default()
    }

    /// The last timestamp issued (zero before the first event).
    pub fn last(&self) -> HlcTimestamp {
        self.last
    }

    /// Stamp a local or send event at physical time `pt_us`.
    pub fn now(&mut self, pt_us: u64) -> HlcTimestamp {
        let l = self.last.l.max(pt_us);
        let c = if l == self.last.l { self.last.c + 1 } else { 0 };
        self.last = HlcTimestamp { l, c };
        self.last
    }

    /// Merge a received remote timestamp at physical time `pt_us`.
    pub fn recv(&mut self, pt_us: u64, remote: HlcTimestamp) -> HlcTimestamp {
        let l = self.last.l.max(remote.l).max(pt_us);
        let c = if l == self.last.l && l == remote.l {
            self.last.c.max(remote.c) + 1
        } else if l == self.last.l {
            self.last.c + 1
        } else if l == remote.l {
            remote.c + 1
        } else {
            0
        };
        self.last = HlcTimestamp { l, c };
        self.last
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_events_are_strictly_monotone() {
        let mut h = Hlc::new();
        let mut prev = h.now(100);
        for pt in [101, 50, 0, 101, 200, 199] {
            let t = h.now(pt);
            assert!(t > prev, "{t} not after {prev} at pt={pt}");
            prev = t;
        }
    }

    #[test]
    fn counter_resets_when_physical_time_advances() {
        let mut h = Hlc::new();
        h.now(10);
        h.now(10);
        assert_eq!(h.last(), HlcTimestamp::new(10, 2));
        assert_eq!(h.now(11), HlcTimestamp::new(11, 0));
    }

    #[test]
    fn backward_physical_jump_keeps_l_and_bumps_c() {
        let mut h = Hlc::new();
        h.now(1000);
        // physical clock steps back 900µs: l must hold, c must advance
        assert_eq!(h.now(100), HlcTimestamp::new(1000, 1));
        assert_eq!(h.now(100), HlcTimestamp::new(1000, 2));
        // physical time catching back up resets the counter
        assert_eq!(h.now(1001), HlcTimestamp::new(1001, 0));
    }

    #[test]
    fn recv_dominates_both_inputs() {
        let mut h = Hlc::new();
        h.now(50);
        let remote = HlcTimestamp::new(80, 3);
        let t = h.recv(60, remote);
        assert!(t > remote && t > HlcTimestamp::new(50, 1));
        assert_eq!(t, HlcTimestamp::new(80, 4), "remote l wins, its c + 1");
    }

    #[test]
    fn recv_counter_rules_cover_all_tie_cases() {
        // tie with both: max of counters + 1
        let mut h = Hlc::new();
        h.now(100); // (100, 0)
        assert_eq!(h.recv(100, HlcTimestamp::new(100, 7)), HlcTimestamp::new(100, 8));
        // tie with ours only
        let mut h = Hlc::new();
        h.now(100);
        assert_eq!(h.recv(0, HlcTimestamp::new(40, 9)), HlcTimestamp::new(100, 1));
        // tie with remote only
        let mut h = Hlc::new();
        h.now(10);
        assert_eq!(h.recv(0, HlcTimestamp::new(90, 2)), HlcTimestamp::new(90, 3));
        // fresh physical time wins outright
        let mut h = Hlc::new();
        h.now(10);
        assert_eq!(h.recv(500, HlcTimestamp::new(90, 2)), HlcTimestamp::new(500, 0));
    }

    #[test]
    fn l_never_exceeds_largest_physical_input() {
        let mut h = Hlc::new();
        let mut max_pt = 0u64;
        for pt in [5, 300, 2, 2, 299, 301, 0] {
            max_pt = max_pt.max(pt);
            h.now(pt);
            assert!(h.last().l <= max_pt, "l={} ran ahead of pt max {max_pt}", h.last().l);
        }
    }

    #[test]
    fn pack_preserves_order_and_roundtrips() {
        let cases = [
            HlcTimestamp::new(0, 0),
            HlcTimestamp::new(0, 1),
            HlcTimestamp::new(1, 0),
            HlcTimestamp::new(1_700_000_000_000_000, 3),
            HlcTimestamp::new(1_700_000_000_000_000, 4),
            HlcTimestamp::new(1_700_000_000_000_001, 0),
        ];
        for pair in cases.windows(2) {
            assert!(pair[0] < pair[1]);
            assert!(pair[0].pack() < pair[1].pack(), "{} vs {}", pair[0], pair[1]);
        }
        for ts in cases {
            assert_eq!(HlcTimestamp::unpack(ts.pack()), ts);
        }
        // counter overflow saturates instead of carrying into l
        let fat = HlcTimestamp::new(7, 1 << 20);
        assert_eq!(HlcTimestamp::unpack(fat.pack()), HlcTimestamp::new(7, (1 << 16) - 1));
    }

    #[test]
    fn wire_roundtrip_and_size() {
        for ts in [
            HlcTimestamp::new(0, 0),
            HlcTimestamp::new(127, 1),
            HlcTimestamp::new(1_700_000_000_000_000, 65535),
        ] {
            let mut buf = Vec::new();
            encode_hlc(&ts, &mut buf);
            assert_eq!(buf.len(), ts.encoded_size());
            let mut pos = 0;
            assert_eq!(decode_hlc(&buf, &mut pos).unwrap(), ts);
            assert_eq!(pos, buf.len());
        }
        // truncation is an error, never a panic
        let mut buf = Vec::new();
        encode_hlc(&HlcTimestamp::new(300, 300), &mut buf);
        for cut in 0..buf.len() {
            let mut pos = 0;
            assert!(decode_hlc(&buf[..cut], &mut pos).is_err(), "prefix {cut}");
        }
    }
}
