//! Lamport clocks (§3.1): causally-compliant total order without real time.
//!
//! "An alternative approach that avoids real time clock synchronization
//! ... would be to use Lamport clocks, establishing a total order among
//! updates that is compliant with causal dependencies": the pair
//! `(CLOCK, REPLICA)` ordered lexicographically. Like the wall-clock
//! variant, the order is total, so genuinely concurrent updates are
//! (silently) linearized — the paper's point.

use std::fmt;

use super::{Actor, ClockOrd, LogicalClock};

/// `(counter, replica)` Lamport pair; ordered lexicographically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct LamportClock {
    /// The logical counter.
    pub counter: u64,
    /// Site id (client or coordinating replica).
    pub actor: Actor,
}

impl LamportClock {
    /// Construct a pair.
    pub fn new(counter: u64, actor: Actor) -> LamportClock {
        LamportClock { counter, actor }
    }

    /// The clock for a new update given the context's counter and the
    /// issuing site: `max(seen, local) + 1` (the standard receive rule;
    /// here the store's per-key counter stands in for "local").
    pub fn tick(seen: u64, local: u64, actor: Actor) -> LamportClock {
        LamportClock { counter: seen.max(local) + 1, actor }
    }
}

impl LogicalClock for LamportClock {
    fn compare(&self, other: &LamportClock) -> ClockOrd {
        match Ord::cmp(self, other) {
            std::cmp::Ordering::Less => ClockOrd::Less,
            std::cmp::Ordering::Greater => ClockOrd::Greater,
            std::cmp::Ordering::Equal => ClockOrd::Equal,
        }
    }

    fn encoded_size(&self) -> usize {
        super::encoding::varint_len(self.counter) + super::encoding::varint_len(self.actor.0 as u64)
    }
}

impl fmt::Display for LamportClock {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({},{})", self.counter, self.actor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_order_rule() {
        // (ca, ra) < (cb, rb) iff ca < cb or (ca = cb and ra < rb)
        let x = LamportClock::new(1, Actor::server(1));
        let y = LamportClock::new(2, Actor::server(0));
        let z = LamportClock::new(2, Actor::server(1));
        assert_eq!(x.compare(&y), ClockOrd::Less);
        assert_eq!(y.compare(&z), ClockOrd::Less);
        assert_eq!(z.compare(&z), ClockOrd::Equal);
    }

    #[test]
    fn tick_is_monotone() {
        let c = LamportClock::tick(5, 3, Actor::server(0));
        assert_eq!(c.counter, 6);
        let c2 = LamportClock::tick(2, 9, Actor::server(0));
        assert_eq!(c2.counter, 10);
        assert!(LamportClock::new(5, Actor::server(0)).compare(&c).is_leq());
    }

    #[test]
    fn causal_compliance() {
        // a write that causally follows another always orders after it
        let first = LamportClock::tick(0, 0, Actor::server(0));
        let second = LamportClock::tick(first.counter, 0, Actor::server(1));
        assert_eq!(first.compare(&second), ClockOrd::Less);
    }
}
