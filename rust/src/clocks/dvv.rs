//! Dotted version vectors (§5): the paper's contribution.
//!
//! A DVV is a version vector plus (at most) one *dot* — an isolated event
//! that may sit beyond the contiguous range of its actor: the triple
//! `(r, m, n)` of the paper is represented here as the vector entry
//! `(r, m)` plus `dot = (r, n)`, `n > m`. "Dotted version vectors can also
//! be thought of as a standard version vector augmented by a pair
//! identifier-counter to describe the single dot needed" (§5.3).
//!
//! The order is defined semantically — `X ≤ Y ⟺ C[[X]] ⊆ C[[Y]]` (§5.2) —
//! and computed without materializing histories. This implementation is the
//! scalar mirror of the vectorized Pallas kernel
//! (`python/compile/kernels/dominance.py`); `runtime::batch` packs these
//! clocks into the shared tensor encoding.

use std::fmt;

use super::{Actor, CausalHistory, ClockOrd, Event, LogicalClock, VersionVector};

/// A dotted version vector.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Dvv {
    /// Contiguous ranges per actor (the classic version-vector part).
    pub vv: VersionVector,
    /// The single isolated event, if any: `(actor, n)` with
    /// `n > vv.get(actor)`.
    pub dot: Option<(Actor, u64)>,
}

impl Dvv {
    /// Empty clock (no events).
    pub fn new() -> Dvv {
        Dvv::default()
    }

    /// A pure version vector (no dot).
    pub fn from_vv(vv: VersionVector) -> Dvv {
        Dvv { vv, dot: None }
    }

    /// The paper's §5.3 `update` construction: context vector + a new dot
    /// at `coord` numbered `n` (callers supply `n = ⌈S_r⌉_coord + 1`).
    pub fn with_dot(context: VersionVector, coord: Actor, n: u64) -> Dvv {
        debug_assert!(
            n > context.get(coord),
            "dot {n} must exceed the context range {} for {coord}",
            context.get(coord)
        );
        Dvv { vv: context, dot: Some((coord, n)) }
    }

    /// `⌈self⌉_r`: the maximum integer recorded for `r` (§5.3).
    pub fn ceil(&self, r: Actor) -> u64 {
        let base = self.vv.get(r);
        match self.dot {
            Some((a, n)) if a == r => base.max(n),
            _ => base,
        }
    }

    /// Contiguous coverage for actor `r`: the largest `k` such that events
    /// `r_1..r_k` are all in `C[[self]]`.
    fn contiguous(&self, r: Actor) -> u64 {
        let m = self.vv.get(r);
        match self.dot {
            Some((a, n)) if a == r && n == m + 1 => n,
            _ => m,
        }
    }

    /// Does `C[[self]]` contain event `r_seq`?
    pub fn contains(&self, e: &Event) -> bool {
        e.seq <= self.vv.get(e.actor) || self.dot == Some((e.actor, e.seq))
    }

    /// Non-strict domination: `C[[self]] ⊆ C[[other]]`.
    pub fn dominated_by(&self, other: &Dvv) -> bool {
        // every contiguous range of self must fit in other's coverage
        let ranges_ok = self
            .vv
            .iter()
            .all(|(r, m)| m <= other.contiguous(r));
        if !ranges_ok {
            return false;
        }
        // self's dot must be present in other
        match self.dot {
            None => true,
            Some((r, n)) => n <= other.vv.get(r) || other.dot == Some((r, n)),
        }
    }

    /// Normalize: fold a contiguous dot `(r, m+1)` into the vector part.
    /// The represented history is unchanged.
    pub fn compact(&mut self) {
        if let Some((r, n)) = self.dot {
            if n == self.vv.get(r) + 1 {
                self.vv.set(r, n);
                self.dot = None;
            }
        }
    }

    /// The join-ceiling vector `{(i, ⌈self⌉_i)}` — what a GET context
    /// contributes for this clock (valid because replica sets are
    /// downsets, §5.4).
    pub fn ceil_vv(&self) -> VersionVector {
        let mut out = self.vv.clone();
        if let Some((r, n)) = self.dot {
            if n > out.get(r) {
                out.set(r, n);
            }
        }
        out
    }

    /// Join this clock's ceiling into `acc` without allocating — the GET
    /// hot path (`DvvMech::read` folds every sibling through this).
    pub fn join_ceil_into(&self, acc: &mut VersionVector) {
        acc.join_from(&self.vv);
        if let Some((r, n)) = self.dot {
            if n > acc.get(r) {
                acc.set(r, n);
            }
        }
    }

    /// Materialized causal history `C[[self]]` (oracle cross-checks only).
    pub fn history(&self) -> CausalHistory {
        let mut h = self.vv.history();
        if let Some((r, n)) = self.dot {
            h.insert(Event::new(r, n));
        }
        h
    }
}

impl LogicalClock for Dvv {
    fn compare(&self, other: &Dvv) -> ClockOrd {
        ClockOrd::from_leq_geq(self.dominated_by(other), other.dominated_by(self))
    }

    fn encoded_size(&self) -> usize {
        self.vv.encoded_size()
            + 1 // dot-present flag
            + self
                .dot
                .map(|(a, n)| {
                    super::encoding::varint_len(a.0 as u64) + super::encoding::varint_len(n)
                })
                .unwrap_or(0)
    }
}

impl fmt::Display for Dvv {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Paper notation: {(a,2),(b,1),(c,3,7)} — the dotted actor renders
        // as a triple (m may be 0 and is still shown, e.g. (b,0,2)).
        write!(f, "{{")?;
        let mut first = true;
        let mut dotted_done = false;
        for (a, m) in self.vv.iter() {
            if !first {
                write!(f, ",")?;
            }
            first = false;
            match self.dot {
                Some((da, n)) if da == a => {
                    write!(f, "({a},{m},{n})")?;
                    dotted_done = true;
                }
                _ => write!(f, "({a},{m})")?,
            }
        }
        if let Some((da, n)) = self.dot {
            if !dotted_done {
                if !first {
                    write!(f, ",")?;
                }
                write!(f, "({da},0,{n})")?;
            }
        }
        write!(f, "}}")
    }
}

/// Shorthand for tests/figures: a dotted clock from vector pairs + dot.
pub fn dvv(pairs: &[(Actor, u64)], dot: Option<(Actor, u64)>) -> Dvv {
    Dvv { vv: super::vv::vv(pairs), dot }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::vv::vv;
    use crate::testkit::prop::{forall, from_fn, Config};
    use crate::testkit::Rng;

    fn a() -> Actor {
        Actor::server(0)
    }
    fn b() -> Actor {
        Actor::server(1)
    }
    fn c() -> Actor {
        Actor::server(2)
    }

    #[test]
    fn section_5_1_example_history() {
        // {(a,2),(b,1),(c,3,7)} represents {a1,a2,b1,c1,c2,c3,c7}
        let x = dvv(&[(a(), 2), (b(), 1), (c(), 3)], Some((c(), 7)));
        let h = x.history();
        assert_eq!(h.len(), 7);
        assert!(h.contains(&Event::new(c(), 7)));
        assert!(!h.contains(&Event::new(c(), 4)));
    }

    #[test]
    fn section_5_2_same_replica_concurrency() {
        // {(r,4)} || {(r,3,5)}
        let x = dvv(&[(a(), 4)], None);
        let y = dvv(&[(a(), 3)], Some((a(), 5)));
        assert_eq!(x.compare(&y), ClockOrd::Concurrent);
        assert_eq!(y.compare(&x), ClockOrd::Concurrent);
    }

    #[test]
    fn contiguous_dot_equals_range() {
        // (r,3,4) has the same history as (r,4)
        let dotted = dvv(&[(a(), 3)], Some((a(), 4)));
        let range = dvv(&[(a(), 4)], None);
        assert_eq!(dotted.compare(&range), ClockOrd::Equal);
        let mut compacted = dotted.clone();
        compacted.compact();
        assert_eq!(compacted, range);
    }

    #[test]
    fn compact_keeps_noncontiguous_dot() {
        let mut x = dvv(&[(a(), 3)], Some((a(), 5)));
        x.compact();
        assert_eq!(x.dot, Some((a(), 5)));
    }

    #[test]
    fn figure7_final_relations() {
        // v=(b,0,1), w=(b,0,2), y=(a,1,2), z={(a,0,3),(b,2)}
        let v = dvv(&[], Some((b(), 1)));
        let w = dvv(&[], Some((b(), 2)));
        let y = dvv(&[(a(), 1)], Some((a(), 2)));
        let z = dvv(&[(b(), 2)], Some((a(), 3)));
        assert_eq!(v.compare(&w), ClockOrd::Concurrent);
        assert_eq!(v.compare(&z), ClockOrd::Less);
        assert_eq!(w.compare(&z), ClockOrd::Less);
        assert_eq!(y.compare(&z), ClockOrd::Concurrent);
        assert_eq!(y.compare(&v), ClockOrd::Concurrent);
    }

    #[test]
    fn ceil_accounts_for_dot() {
        let x = dvv(&[(a(), 2)], Some((a(), 7)));
        assert_eq!(x.ceil(a()), 7);
        assert_eq!(x.ceil(b()), 0);
        assert_eq!(x.ceil_vv(), vv(&[(a(), 7)]));
    }

    #[test]
    fn update_construction_dot_exceeds_context() {
        let u = Dvv::with_dot(vv(&[(a(), 1)]), a(), 2);
        assert_eq!(u.to_string(), "{(a,1,2)}");
        assert!(u.contains(&Event::new(a(), 2)));
    }

    #[test]
    fn display_matches_paper() {
        assert_eq!(dvv(&[], Some((b(), 2))).to_string(), "{(b,0,2)}");
        assert_eq!(
            dvv(&[(a(), 2), (b(), 1), (c(), 3)], Some((c(), 7))).to_string(),
            "{(a,2),(b,1),(c,3,7)}"
        );
        assert_eq!(dvv(&[(b(), 2)], Some((a(), 3))).to_string(), "{(b,2),(a,0,3)}");
    }

    fn arb_dvv(rng: &mut Rng, _size: usize) -> Dvv {
        let actors = 3u32;
        let vvp = VersionVector::from_pairs(
            (0..actors).map(|i| (Actor::server(i), rng.below(5))),
        );
        let dot = if rng.chance(0.6) {
            let r = Actor::server(rng.below(actors as u64) as u32);
            let n = vvp.get(r) + 1 + rng.below(4);
            Some((r, n))
        } else {
            None
        };
        Dvv { vv: vvp, dot }
    }

    #[test]
    fn prop_compare_agrees_with_history_inclusion() {
        forall(
            &Config::default().cases(300),
            from_fn(|rng, size| (arb_dvv(rng, size), arb_dvv(rng, size))),
            |(x, y)| x.compare(y) == x.history().compare(&y.history()),
        );
    }

    #[test]
    fn prop_compact_preserves_history_and_order() {
        forall(
            &Config::default().cases(200),
            from_fn(|rng, size| (arb_dvv(rng, size), arb_dvv(rng, size))),
            |(x, y)| {
                let mut xc = x.clone();
                xc.compact();
                xc.history() == x.history() && xc.compare(y) == x.compare(y)
            },
        );
    }

    #[test]
    fn prop_ceil_vv_dominates() {
        forall(
            &Config::default().cases(200),
            from_fn(|rng, size| arb_dvv(rng, size)),
            |x| x.history().is_subset(&x.ceil_vv().history()),
        );
    }

    #[test]
    fn encoded_size_is_replica_bounded() {
        // the paper's headline: metadata linear in replicas, not clients
        let x = dvv(&[(a(), 1000), (b(), 2000), (c(), 500)], Some((a(), 1002)));
        assert!(x.encoded_size() < 32, "got {}", x.encoded_size());
    }
}
