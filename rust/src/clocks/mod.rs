//! Causality-tracking mechanisms.
//!
//! One module per mechanism the paper surveys (§3) plus the contribution
//! (§5):
//!
//! | module            | paper section | mechanism                           |
//! |-------------------|---------------|-------------------------------------|
//! | [`causal_history`]| §3 intro      | explicit event sets (ground truth)  |
//! | [`realtime`]      | §3.1          | physical-clock last-writer-wins     |
//! | [`lamport`]       | §3.1          | Lamport-clock total order           |
//! | [`vv`]            | §3.2          | version vectors, per-server entries |
//! | [`dvv`]           | §5            | **dotted version vectors**          |
//! | [`dvvset`]        | extension     | compact sibling-set DVVs            |
//!
//! The per-client version-vector variant of §3.3 reuses [`VersionVector`]
//! over client actors; its server-side behaviour lives in
//! `kernel::mechs::client_vv`. [`encoding`] provides the wire codecs used
//! for the metadata-size experiments (DESIGN.md E7). [`hlc`] is not a
//! causality mechanism at all: it is the hybrid logical clock the
//! geo-replication subsystem stamps cross-DC shipments with.

pub mod causal_history;
pub mod dvv;
pub mod dvvset;
pub mod encoding;
pub mod hlc;
pub mod lamport;
pub mod realtime;
pub mod vv;

pub use causal_history::CausalHistory;
pub use dvv::Dvv;
pub use dvvset::DvvSet;
pub use hlc::{Hlc, HlcTimestamp};
pub use lamport::LamportClock;
pub use realtime::RtClock;
pub use vv::VersionVector;

use std::fmt;

/// A participant identifier: a replica server or a client.
///
/// The paper's three orders of magnitude (§2) — few replicas per key, many
/// servers, a huge number of clients — are modelled by one compact id
/// space: servers occupy low ids, clients start at [`Actor::CLIENT_BASE`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Actor(pub u32);

impl Actor {
    /// First id used for clients (servers sit below this).
    pub const CLIENT_BASE: u32 = 1 << 20;

    /// A server actor (`a`, `b`, `c`, ... in the paper's figures).
    pub fn server(i: u32) -> Actor {
        debug_assert!(i < Actor::CLIENT_BASE);
        Actor(i)
    }

    /// A client actor (`C1`, `C2`, ... in the paper's figures).
    pub fn client(i: u32) -> Actor {
        Actor(Actor::CLIENT_BASE + i)
    }

    /// Is this a client id?
    pub fn is_client(self) -> bool {
        self.0 >= Actor::CLIENT_BASE
    }
}

impl fmt::Display for Actor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_client() {
            write!(f, "C{}", self.0 - Actor::CLIENT_BASE + 1)
        } else if self.0 < 26 {
            write!(f, "{}", (b'a' + self.0 as u8) as char)
        } else {
            write!(f, "s{}", self.0)
        }
    }
}

/// A globally unique update event: `b_3` in the paper's notation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Event {
    /// Actor that generated the event.
    pub actor: Actor,
    /// Per-actor monotonic sequence number, starting at 1.
    pub seq: u64,
}

impl Event {
    /// Construct `actor_seq`.
    pub fn new(actor: Actor, seq: u64) -> Event {
        debug_assert!(seq >= 1, "event sequence numbers start at 1");
        Event { actor, seq }
    }
}

impl fmt::Display for Event {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{}", self.actor, self.seq)
    }
}

/// Outcome of comparing two clocks under the causality partial order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockOrd {
    /// Identical causal histories.
    Equal,
    /// Self's history is strictly contained in the other's.
    Less,
    /// Self's history strictly contains the other's.
    Greater,
    /// Neither contains the other: concurrent updates.
    Concurrent,
}

impl ClockOrd {
    /// `self <= other` (non-strict domination).
    pub fn is_leq(self) -> bool {
        matches!(self, ClockOrd::Equal | ClockOrd::Less)
    }

    /// `self >= other`.
    pub fn is_geq(self) -> bool {
        matches!(self, ClockOrd::Equal | ClockOrd::Greater)
    }

    /// The comparison seen from the other side.
    pub fn flip(self) -> ClockOrd {
        match self {
            ClockOrd::Less => ClockOrd::Greater,
            ClockOrd::Greater => ClockOrd::Less,
            other => other,
        }
    }

    /// Build from the two non-strict domination directions.
    pub fn from_leq_geq(leq: bool, geq: bool) -> ClockOrd {
        match (leq, geq) {
            (true, true) => ClockOrd::Equal,
            (true, false) => ClockOrd::Less,
            (false, true) => ClockOrd::Greater,
            (false, false) => ClockOrd::Concurrent,
        }
    }
}

/// A logical clock: orderable, sizeable, and (where faithful) convertible
/// to its causal history for oracle cross-checks.
pub trait LogicalClock: Clone + fmt::Debug {
    /// Compare under the mechanism's (partial or total) order.
    fn compare(&self, other: &Self) -> ClockOrd;

    /// Encoded wire size in bytes (metadata-size experiments, E7).
    fn encoded_size(&self) -> usize;
}

/// Names accepted by `--mechanism` / `cluster.mechanism` config.
pub const MECHANISM_NAMES: &[&str] =
    &["history", "lww", "lamport", "vv", "clientvv", "dvv", "dvvset"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn actor_display_matches_paper_notation() {
        assert_eq!(Actor::server(0).to_string(), "a");
        assert_eq!(Actor::server(1).to_string(), "b");
        assert_eq!(Actor::client(0).to_string(), "C1");
        assert_eq!(Actor::client(2).to_string(), "C3");
    }

    #[test]
    fn event_display() {
        assert_eq!(Event::new(Actor::server(1), 2).to_string(), "b2");
    }

    #[test]
    fn client_server_spaces_disjoint() {
        assert!(!Actor::server(999).is_client());
        assert!(Actor::client(0).is_client());
        assert_ne!(Actor::server(5), Actor::client(5));
    }

    #[test]
    fn clockord_helpers() {
        assert!(ClockOrd::Equal.is_leq() && ClockOrd::Equal.is_geq());
        assert!(ClockOrd::Less.is_leq() && !ClockOrd::Less.is_geq());
        assert_eq!(ClockOrd::Less.flip(), ClockOrd::Greater);
        assert_eq!(ClockOrd::Concurrent.flip(), ClockOrd::Concurrent);
        assert_eq!(ClockOrd::from_leq_geq(true, false), ClockOrd::Less);
        assert_eq!(ClockOrd::from_leq_geq(false, false), ClockOrd::Concurrent);
    }
}
