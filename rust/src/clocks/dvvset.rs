//! Dotted version vector *sets* — the compact sibling-set extension.
//!
//! The paper's conclusion points at follow-up work on representing the
//! whole set of siblings with a single structure; this module implements
//! that optimization (the DVVSet of Almeida, Baquero, Gonçalves, Fonte,
//! Preguiça — "Scalable and Accurate Causality Tracking for Eventually
//! Consistent Stores"). Instead of one full DVV per sibling, the per-key
//! state is one list of `(actor, n, values)` entries:
//!
//! * `n` — the contiguous range `1..=n` of events this set knows for
//!   `actor`;
//! * `values` — the live sibling values for the most recent dots of
//!   `actor`: `values[0]` carries dot `(actor, n)`, `values[1]` carries
//!   `(actor, n-1)`, and so on. Events below `n - values.len()` are
//!   *covered without a value* — they were overwritten.
//!
//! Dots are positional, so sibling metadata costs O(replicas) total rather
//! than O(replicas × siblings) — the ablation measured in E7.

use std::fmt;

use super::{Actor, VersionVector};

/// One actor's column of the set.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Entry<V> {
    actor: Actor,
    /// Contiguous events known: `1..=n`.
    n: u64,
    /// Live values; `vals[i]` holds the value written by event `n - i`.
    vals: Vec<V>,
}

impl<V> Entry<V> {
    /// Sequence number below which every event is dead (overwritten).
    fn dead_below(&self) -> u64 {
        self.n - self.vals.len() as u64
    }
}

/// A compact sibling set with positional dots.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DvvSet<V> {
    /// Sorted by actor.
    entries: Vec<Entry<V>>,
}

impl<V> Default for DvvSet<V> {
    fn default() -> Self {
        DvvSet { entries: Vec::new() }
    }
}

impl<V: Clone + fmt::Debug> DvvSet<V> {
    /// Empty set.
    pub fn new() -> DvvSet<V> {
        DvvSet { entries: Vec::new() }
    }

    /// The set's version vector `{(r, n_r)}` — also the GET context.
    pub fn vv(&self) -> VersionVector {
        VersionVector::from_pairs(self.entries.iter().map(|e| (e.actor, e.n)))
    }

    /// All live sibling values (most recent dot first per actor).
    pub fn values(&self) -> Vec<&V> {
        self.entries.iter().flat_map(|e| e.vals.iter()).collect()
    }

    /// Number of live siblings.
    pub fn len(&self) -> usize {
        self.entries.iter().map(|e| e.vals.len()).sum()
    }

    /// No live values?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `⌈S⌉_r` — max counter recorded for `r`.
    pub fn ceil(&self, r: Actor) -> u64 {
        self.entry(r).map(|e| e.n).unwrap_or(0)
    }

    fn entry(&self, r: Actor) -> Option<&Entry<V>> {
        self.entries
            .binary_search_by_key(&r, |e| e.actor)
            .ok()
            .map(|i| &self.entries[i])
    }

    fn entry_mut(&mut self, r: Actor) -> &mut Entry<V> {
        match self.entries.binary_search_by_key(&r, |e| e.actor) {
            Ok(i) => &mut self.entries[i],
            Err(i) => {
                self.entries.insert(i, Entry { actor: r, n: 0, vals: Vec::new() });
                &mut self.entries[i]
            }
        }
    }

    /// The paper-kernel `update` + `sync` fused, DVVSet-style: register a
    /// new value at coordinator `coord` with the client's read context
    /// `ctx`, discarding siblings the context covers.
    pub fn update(&mut self, ctx: &VersionVector, val: V, coord: Actor) {
        // new event (coord, n+1) carries `val`
        let e = self.entry_mut(coord);
        e.n += 1;
        e.vals.insert(0, val);
        // discard dots covered by the context (they were read and
        // superseded by this write)
        for e in &mut self.entries {
            let covered = ctx.get(e.actor);
            // dots are e.n, e.n-1, ..; keep those with seq > covered
            let keep = (e.n.saturating_sub(covered)).min(e.vals.len() as u64) as usize;
            e.vals.truncate(keep);
        }
        self.entries.retain(|e| e.n > 0);
    }

    /// Replica-to-replica merge (the paper-kernel `sync` over whole sets).
    /// A dot survives iff it is live on every side that knows it.
    pub fn sync_from(&mut self, other: &DvvSet<V>) {
        for oe in &other.entries {
            let se = self.entry_mut(oe.actor);
            if se.n == 0 {
                // unseen actor: adopt wholesale
                se.n = oe.n;
                se.vals = oe.vals.clone();
                continue;
            }
            let dead = se.dead_below().max(oe.dead_below());
            let n = se.n.max(oe.n);
            let live = (n - dead) as usize;
            let mut vals = Vec::with_capacity(live.min(se.vals.len() + oe.vals.len()));
            for seq in ((dead + 1)..=n).rev() {
                // prefer own copy; identical events carry identical values
                if seq <= se.n && (se.n - seq) < se.vals.len() as u64 {
                    vals.push(se.vals[(se.n - seq) as usize].clone());
                } else if seq <= oe.n && (oe.n - seq) < oe.vals.len() as u64 {
                    vals.push(oe.vals[(oe.n - seq) as usize].clone());
                }
                // else: dot known but value dead on the knowing side
            }
            se.n = n;
            se.vals = vals;
        }
        self.entries.retain(|e| e.n > 0);
    }

    /// The per-actor columns `(actor, n, live values)` in ascending actor
    /// order — the raw representation a state codec serializes
    /// ([`crate::kernel::DurableMechanism`]).
    pub fn columns(&self) -> impl Iterator<Item = (Actor, u64, &[V])> {
        self.entries.iter().map(|e| (e.actor, e.n, e.vals.as_slice()))
    }

    /// Append one column during decode. Columns must arrive in strictly
    /// ascending actor order with `n >= vals.len()` and `n > 0` (the
    /// invariants [`columns`](DvvSet::columns) emits); anything else is a
    /// corrupt encoding and errors instead of building an invalid set.
    pub fn push_column(&mut self, actor: Actor, n: u64, vals: Vec<V>) -> crate::Result<()> {
        if n == 0 || (vals.len() as u64) > n {
            return Err(crate::Error::Codec(format!(
                "dvvset column for {actor}: n={n} cannot cover {} values",
                vals.len()
            )));
        }
        if let Some(last) = self.entries.last() {
            if last.actor >= actor {
                return Err(crate::Error::Codec(format!(
                    "dvvset columns out of order: {} then {actor}",
                    last.actor
                )));
            }
        }
        self.entries.push(Entry { actor, n, vals });
        Ok(())
    }

    /// Encoded metadata size: per-actor id + counter + per-value 1-byte
    /// liveness marker (values themselves excluded — metadata only).
    pub fn metadata_bytes(&self) -> usize {
        super::encoding::varint_len(self.entries.len() as u64)
            + self
                .entries
                .iter()
                .map(|e| {
                    super::encoding::varint_len(e.actor.0 as u64)
                        + super::encoding::varint_len(e.n)
                        + super::encoding::varint_len(e.vals.len() as u64)
                })
                .sum::<usize>()
    }
}

impl<V: Clone + fmt::Debug> fmt::Display for DvvSet<V> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (i, e) in self.entries.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "({},{},{:?})", e.actor, e.n, e.vals)?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::vv::vv;

    fn a() -> Actor {
        Actor::server(0)
    }
    fn b() -> Actor {
        Actor::server(1)
    }

    #[test]
    fn first_write_creates_dot() {
        let mut s: DvvSet<&str> = DvvSet::new();
        s.update(&VersionVector::new(), "v", b());
        assert_eq!(s.values(), vec![&"v"]);
        assert_eq!(s.vv(), vv(&[(b(), 1)]));
    }

    #[test]
    fn blind_write_keeps_sibling() {
        // the Fig. 1/7 scenario: two clients write with empty context
        let mut s: DvvSet<&str> = DvvSet::new();
        s.update(&VersionVector::new(), "v", b());
        s.update(&VersionVector::new(), "w", b());
        assert_eq!(s.len(), 2, "{s}");
        assert_eq!(s.vv(), vv(&[(b(), 2)]));
    }

    #[test]
    fn informed_write_overwrites() {
        let mut s: DvvSet<&str> = DvvSet::new();
        s.update(&VersionVector::new(), "x", a());
        let ctx = s.vv();
        s.update(&ctx, "y", a());
        assert_eq!(s.values(), vec![&"y"]);
        assert_eq!(s.vv(), vv(&[(a(), 2)]));
    }

    #[test]
    fn context_covering_all_siblings_collapses_them() {
        let mut s: DvvSet<&str> = DvvSet::new();
        s.update(&VersionVector::new(), "v", b());
        s.update(&VersionVector::new(), "w", b());
        let ctx = s.vv(); // read both siblings
        s.update(&ctx, "z", a());
        assert_eq!(s.values(), vec![&"z"]);
        assert_eq!(s.vv(), vv(&[(a(), 1), (b(), 2)]));
    }

    #[test]
    fn sync_is_idempotent_and_commutative() {
        let mut s1: DvvSet<&str> = DvvSet::new();
        s1.update(&VersionVector::new(), "v", b());
        let mut s2 = s1.clone();
        s2.update(&s2.vv(), "y", a());
        let mut m1 = s1.clone();
        m1.sync_from(&s2);
        let mut m2 = s2.clone();
        m2.sync_from(&s1);
        assert_eq!(m1, m2);
        let snapshot = m1.clone();
        m1.sync_from(&s2);
        assert_eq!(m1, snapshot);
    }

    #[test]
    fn sync_kills_dots_dead_on_either_side() {
        // s1 holds v=(b,1); s2 saw v and overwrote it with y=(a,1)
        let mut s1: DvvSet<&str> = DvvSet::new();
        s1.update(&VersionVector::new(), "v", b());
        let mut s2 = s1.clone();
        s2.update(&s2.vv(), "y", a());
        s1.sync_from(&s2);
        assert_eq!(s1.values(), vec![&"y"], "{s1}");
    }

    #[test]
    fn sync_keeps_concurrent_dots() {
        let mut s1: DvvSet<&str> = DvvSet::new();
        s1.update(&VersionVector::new(), "v", b());
        let mut s2: DvvSet<&str> = DvvSet::new();
        s2.update(&VersionVector::new(), "y", a());
        s1.sync_from(&s2);
        assert_eq!(s1.len(), 2);
    }

    #[test]
    fn metadata_size_constant_in_siblings_per_actor() {
        // DVVSet's win over plain per-sibling DVVs
        let mut s: DvvSet<u64> = DvvSet::new();
        for i in 0..50 {
            s.update(&VersionVector::new(), i, b());
        }
        assert_eq!(s.len(), 50);
        assert!(s.metadata_bytes() < 16, "got {}", s.metadata_bytes());
    }

    #[test]
    fn ceil_tracks_max() {
        let mut s: DvvSet<&str> = DvvSet::new();
        s.update(&VersionVector::new(), "v", b());
        s.update(&VersionVector::new(), "w", b());
        assert_eq!(s.ceil(b()), 2);
        assert_eq!(s.ceil(a()), 0);
    }
}
