//! Physical-timestamp clocks for last-writer-wins (§3.1).
//!
//! "The simplest total order is obtained assuming that client clocks are
//! well synchronized and applying real time clock order (simultaneous
//! events are usually further ordered over process ids)." Used by the
//! Cassandra-style LWW baseline; the §3.1 anomaly (skewed clocks losing
//! all their writes) is reproduced by the simulator's per-client skew
//! injection (`net::ClockSkew`).
//!
//! The order is **total**: `compare` never returns
//! [`ClockOrd::Concurrent`], which is exactly how this mechanism loses
//! concurrent updates (paper Figure 2).

use std::fmt;

use super::{Actor, ClockOrd, LogicalClock};

/// A wall-clock timestamp plus a process-id tiebreak.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct RtClock {
    /// Microseconds of (possibly skewed) wall-clock time.
    pub micros: u64,
    /// Tiebreak for simultaneous events.
    pub actor: Actor,
}

impl RtClock {
    /// Construct from a timestamp and writer id.
    pub fn new(micros: u64, actor: Actor) -> RtClock {
        RtClock { micros, actor }
    }
}

impl LogicalClock for RtClock {
    fn compare(&self, other: &RtClock) -> ClockOrd {
        match Ord::cmp(self, other) {
            std::cmp::Ordering::Less => ClockOrd::Less,
            std::cmp::Ordering::Greater => ClockOrd::Greater,
            std::cmp::Ordering::Equal => ClockOrd::Equal,
        }
    }

    fn encoded_size(&self) -> usize {
        super::encoding::varint_len(self.micros) + super::encoding::varint_len(self.actor.0 as u64)
    }
}

impl fmt::Display for RtClock {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "t{}@{}", self.micros, self.actor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_order_never_concurrent() {
        let x = RtClock::new(10, Actor::client(0));
        let y = RtClock::new(10, Actor::client(1));
        let z = RtClock::new(9, Actor::client(9));
        assert_eq!(x.compare(&y), ClockOrd::Less); // id tiebreak
        assert_eq!(x.compare(&x), ClockOrd::Equal);
        assert_eq!(x.compare(&z), ClockOrd::Greater);
    }

    #[test]
    fn timestamp_dominates_tiebreak() {
        let early_big_id = RtClock::new(5, Actor::client(999));
        let late_small_id = RtClock::new(6, Actor::client(0));
        assert_eq!(early_big_id.compare(&late_small_id), ClockOrd::Less);
    }

    #[test]
    fn encoded_size_is_constant_order() {
        let x = RtClock::new(1_700_000_000_000_000, Actor::client(12345));
        assert!(x.encoded_size() <= 12);
    }
}
