//! `dvv-store` CLI: figure replays, cluster simulation, and the TCP
//! server mode.
//!
//! ```text
//! dvv-store figures [--fig 7|all]
//! dvv-store sim [--mechanism dvv|all] [--nodes 6] [--replication 3] ...
//! dvv-store serve [--addr 127.0.0.1:7700] [--nodes 3] [--data-dir DIR] ...
//! ```

use std::sync::Arc;

use dvvstore::cli::{Command, Matches};
use dvvstore::config::StoreConfig;
use dvvstore::figures;
use dvvstore::kernel::mechs::{dispatch, MechVisitor};
use dvvstore::kernel::{MechKind, Mechanism};
use dvvstore::server::{
    tcp::{ServeMode, ServeOptions, Server},
    LocalCluster,
};
use dvvstore::sim::Sim;
use dvvstore::store::{FsyncPolicy, ShardedBackend, WalOptions};
use dvvstore::workload::{RandomWorkload, WorkloadSpec};

fn cli() -> Command {
    Command::new("dvv-store", "dotted version vectors store (paper reproduction)")
        .subcommand(
            Command::new("figures", "replay the paper's figures")
                .opt("fig", "all", "figure number (1,2,3,4,7) or 'all'"),
        )
        .subcommand(
            Command::new("sim", "run a simulated cluster workload")
                .opt("mechanism", "dvv", "mechanism name or 'all' to compare")
                .opt("nodes", "6", "server nodes")
                .opt("replication", "3", "replication degree N")
                .opt("read-quorum", "2", "read quorum R")
                .opt("write-quorum", "2", "write quorum W")
                .opt("clients", "16", "concurrent clients")
                .opt("ops", "200", "ops per client")
                .opt("keys", "100", "distinct keys")
                .opt("put-fraction", "0.5", "fraction of PUT ops")
                .opt("read-before-write", "0.5", "informed-write probability")
                .opt("zipf", "0.9", "zipfian skew theta")
                .opt("seed", "42", "rng seed")
                .opt("ae-period-us", "0", "anti-entropy period (0 = off)")
                .opt("skew-us", "0", "client clock skew std-dev (µs)")
                .switch("stateless", "stateless clients (§3.3 inference mode)"),
        )
        .subcommand(
            Command::new("serve", "run the TCP store server")
                .opt("addr", "127.0.0.1:7700", "listen address")
                .opt("nodes", "3", "in-process replica nodes")
                .opt("replication", "3", "replication degree N")
                .opt("read-quorum", "2", "read quorum R")
                .opt("write-quorum", "2", "write quorum W")
                .opt("shards", "64", "lock-striped shards per replica (rounded up to a power of two)")
                .opt_optional(
                    "zones",
                    "comma-separated per-node zone (datacenter) list, e.g. 0,0,1,1 — \
                     enables geo mode: zone-scoped quorums and async cross-DC \
                     shipping; the list length overrides --nodes",
                )
                .opt_optional(
                    "data-dir",
                    "root directory for write-ahead-logged durable replicas \
                     (omit for in-memory nodes)",
                )
                .opt_choice(
                    "backend",
                    "auto",
                    &["auto", "sharded", "durable", "lsm"],
                    "storage backend: sharded (in-memory), durable (map + WAL), or lsm \
                     (memtable + sorted runs; working set may exceed RAM). durable and \
                     lsm need --data-dir; auto picks durable when --data-dir is set, \
                     sharded otherwise",
                )
                .opt(
                    "fsync",
                    "64",
                    "WAL fsync policy: always | never | <n> | every<n> (per n appends)",
                )
                .opt("segment-bytes", "1048576", "WAL segment roll threshold (bytes)")
                .opt(
                    "memtable-bytes",
                    "1048576",
                    "lsm backend: per-shard memtable flush threshold (bytes)",
                )
                .opt_choice(
                    "serve-mode",
                    "reactor",
                    &["reactor", "threads"],
                    "connection handling: poll reactor with pipelining, or legacy thread-per-connection",
                )
                .opt(
                    "reactor-workers",
                    "0",
                    "reactor execution threads (0 = size from available parallelism)",
                ),
        )
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = cli();
    let matches = match cmd.parse(&args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let result = match &matches.subcommand {
        Some((name, sub)) => match name.as_str() {
            "figures" => cmd_figures(sub),
            "sim" => cmd_sim(sub),
            "serve" => cmd_serve(sub),
            _ => unreachable!(),
        },
        None => {
            println!("{}", cmd.help());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_figures(m: &Matches) -> dvvstore::Result<()> {
    let which = m.get_str("fig");
    if which == "all" {
        for fig in figures::REPLAYABLE {
            println!("{}", figures::replay(fig)?.render());
        }
    } else {
        let fig: u32 = m.get_parsed("fig")?;
        println!("{}", figures::replay(fig)?.render());
    }
    Ok(())
}

struct SimRun {
    cfg: StoreConfig,
    spec: WorkloadSpec,
    clients: usize,
    stateful: bool,
    seed: u64,
}

impl MechVisitor for SimRun {
    type Out = dvvstore::Result<String>;

    fn visit<M: Mechanism>(self, mech: M) -> Self::Out {
        let driver = Box::new(RandomWorkload::new(self.spec, self.clients));
        let mut sim = Sim::new(mech, self.cfg, self.clients, self.stateful, driver, self.seed)?;
        sim.start();
        sim.run(u64::MAX);
        sim.settle();
        let lost = sim.audit_permanently_lost();
        Ok(format!(
            "| {:<9} | {:>7} | {:>6} | {:>10} | {:>10} | {:>9} | {:>12} | {:>9}µs |",
            M::NAME,
            sim.metrics.ops(),
            lost,
            sim.metrics.false_concurrent_pairs,
            sim.metrics.true_concurrent_pairs,
            sim.metrics.max_siblings,
            sim.metrics.metadata_bytes,
            sim.metrics.put_latency.percentile(0.5),
        ))
    }
}

fn cmd_sim(m: &Matches) -> dvvstore::Result<()> {
    let mut cfg = StoreConfig::default();
    cfg.cluster.nodes = m.get_parsed("nodes")?;
    cfg.cluster.replication = m.get_parsed("replication")?;
    cfg.cluster.read_quorum = m.get_parsed("read-quorum")?;
    cfg.cluster.write_quorum = m.get_parsed("write-quorum")?;
    cfg.antientropy.period_us = m.get_parsed("ae-period-us")?;
    cfg.net.clock_skew_us = m.get_parsed("skew-us")?;
    cfg.validate()?;
    let spec = WorkloadSpec {
        keys: m.get_parsed("keys")?,
        zipf_theta: m.get_parsed("zipf")?,
        put_fraction: m.get_parsed("put-fraction")?,
        read_before_write: m.get_parsed("read-before-write")?,
        ops_per_client: m.get_parsed("ops")?,
        ..Default::default()
    };
    let clients: usize = m.get_parsed("clients")?;
    let seed: u64 = m.get_parsed("seed")?;
    let stateful = !m.has("stateless");

    let mech_arg = m.get_str("mechanism");
    let kinds: Vec<MechKind> = if mech_arg == "all" {
        MechKind::ALL.to_vec()
    } else {
        vec![MechKind::parse(mech_arg)?]
    };

    println!(
        "| mechanism | ops     | lost   | false_conc | true_conc  | siblings  | metadata(B)  | put_p50     |"
    );
    println!("|---|---|---|---|---|---|---|---|");
    for kind in kinds {
        let row = dispatch(
            kind,
            SimRun {
                cfg: cfg.clone(),
                spec: spec.clone(),
                clients,
                stateful,
                seed,
            },
        )?;
        println!("{row}");
    }
    Ok(())
}

fn cmd_serve(m: &Matches) -> dvvstore::Result<()> {
    let n: usize = m.get_parsed("replication")?;
    let r: usize = m.get_parsed("read-quorum")?;
    let w: usize = m.get_parsed("write-quorum")?;
    let shards: usize = m.get_parsed("shards")?;
    let zones: Option<Vec<usize>> = match m.get("zones") {
        Some(raw) => Some(
            raw.split(',')
                .map(|z| {
                    z.trim().parse::<usize>().map_err(|_| {
                        dvvstore::Error::Config(format!(
                            "--zones: cannot parse {z:?} as a zone id (want e.g. 0,0,1,1)"
                        ))
                    })
                })
                .collect::<dvvstore::Result<_>>()?,
        ),
        None => None,
    };
    let nodes: usize = match &zones {
        Some(z) => z.len(),
        None => m.get_parsed("nodes")?,
    };
    let addr = m.get_str("addr");
    let serve = ServeOptions {
        mode: match m.get_str("serve-mode") {
            "threads" => ServeMode::Threaded,
            _ => ServeMode::Reactor { workers: m.get_parsed("reactor-workers")? },
        },
    };
    let backend = m.get_str("backend");
    match (backend, m.get("data-dir")) {
        ("sharded", Some(_)) => Err(dvvstore::Error::Config(
            "--backend sharded is in-memory; drop --data-dir or pick durable/lsm".into(),
        )),
        ("durable" | "lsm", None) => Err(dvvstore::Error::Config(format!(
            "--backend {backend} persists to disk and needs --data-dir"
        ))),
        ("lsm", Some(dir)) => {
            let opts = dvvstore::store::LsmOptions {
                wal: WalOptions {
                    fsync: FsyncPolicy::parse(m.get_str("fsync"))?,
                    segment_bytes: m.get_parsed("segment-bytes")?,
                },
                memtable_bytes: m.get_parsed("memtable-bytes")?,
                ..Default::default()
            };
            let cluster = Arc::new(match &zones {
                Some(z) => LocalCluster::with_lsm_dir_zoned(z, n, r, w, shards, dir, opts)?,
                None => LocalCluster::with_lsm_dir(nodes, n, r, w, shards, dir, opts)?,
            });
            println!(
                "durability: LSM at {dir} (fsync={}, memtable={}B, durable_bytes={})",
                opts.wal.fsync, opts.memtable_bytes, cluster.wal_bytes()
            );
            run_serve_loop(addr, cluster, serve, nodes, n, r, w)
        }
        ("durable", Some(dir)) | ("auto", Some(dir)) => {
            let opts = WalOptions {
                fsync: FsyncPolicy::parse(m.get_str("fsync"))?,
                segment_bytes: m.get_parsed("segment-bytes")?,
            };
            let cluster = Arc::new(match &zones {
                Some(z) => LocalCluster::with_data_dir_zoned(z, n, r, w, shards, dir, opts)?,
                None => LocalCluster::with_data_dir(nodes, n, r, w, shards, dir, opts)?,
            });
            println!(
                "durability: WAL at {dir} (fsync={}, segment={}B, wal_bytes={})",
                opts.fsync, opts.segment_bytes, cluster.wal_bytes()
            );
            run_serve_loop(addr, cluster, serve, nodes, n, r, w)
        }
        _ => {
            let cluster = Arc::new(match &zones {
                Some(z) => LocalCluster::with_backends_zoned(z, n, r, w, move |_| {
                    ShardedBackend::with_shards(shards)
                })?,
                None => LocalCluster::with_shards(nodes, n, r, w, shards)?,
            });
            run_serve_loop(addr, cluster, serve, nodes, n, r, w)
        }
    }
}

fn run_serve_loop<B: dvvstore::store::StorageBackend<dvvstore::kernel::mechs::DvvMech>>(
    addr: &str,
    cluster: Arc<LocalCluster<B>>,
    serve: ServeOptions,
    nodes: usize,
    n: usize,
    r: usize,
    w: usize,
) -> dvvstore::Result<()> {
    let mode = match serve.mode {
        ServeMode::Reactor { workers: 0 } => "reactor (auto-sized workers)".to_string(),
        ServeMode::Reactor { workers } => format!("reactor ({workers} workers)"),
        ServeMode::Threaded => "thread-per-connection".to_string(),
    };
    let server = Server::start_with(addr, cluster.clone(), serve)?;
    println!(
        "dvv-store serving on {} ({} replicas x {} shards, N={n} R={r} W={w}, {mode})",
        server.addr(),
        nodes,
        cluster.shard_count()
    );
    println!(
        "protocol: binary v2 (open with \"DVV2\" + version byte; length-prefixed \
         frames, negotiated per connection — see README \"Wire protocol\")"
    );
    println!("fallback: text — GET <key> | PUT <key> <value-hex> [ctx-hex] | STATS | QUIT");
    println!(
        "chaos:    FAULT CRASH <node> | FAULT PARTITION <a,b> <c,d> | \
         FAULT DROP <prob> | FAULT DELAY <us> | HEAL [node] | \
         RESTART <node> | WIPE <node>"
    );
    if cluster.geo() {
        println!(
            "geo:      {} zones, zone-scoped quorums, async cross-DC shipper \
             (ship_lag in STATS)",
            cluster.zone_count()
        );
    }
    // serve until killed. Maintenance: drain parked sloppy-quorum hints
    // and ship parked cross-DC updates every second (without this, hints
    // from FAULT windows and geo writes' remote homes would accumulate
    // until an operator HEALs); run a full anti-entropy round right
    // after fault activity (pending hints or shipper backlog) and
    // otherwise only at a slow cadence, so an idle fault-free server
    // does not pay all-pairs key diffing every second.
    let mut tick = 0u64;
    loop {
        std::thread::sleep(std::time::Duration::from_secs(1));
        tick += 1;
        let fault_activity = cluster.pending_hints() > 0 || cluster.ship_lag() > 0;
        cluster.drain_hints();
        cluster.ship_round();
        if fault_activity || tick % 30 == 0 {
            cluster.anti_entropy_round();
        }
    }
}
