//! Simulated network: latency sampling, message drops, partitions, and
//! per-client wall-clock skew (for the §3.1 LWW anomaly).
//!
//! The model is intentionally simple and fully deterministic given a seed:
//! one-way delays are exponentially distributed around a configured mean;
//! partitions are symmetric sets of blocked node pairs; skew is a fixed
//! per-client offset drawn once from a normal distribution.

use crate::cluster::NodeId;
use crate::config::NetConfig;
use crate::testkit::Rng;

/// Symmetric set of blocked (partitioned) unordered node pairs — the one
/// definition of partition semantics, shared by the simulator's
/// [`NetModel`] and the threaded cluster's
/// [`Fabric`](crate::server::fabric::Fabric) so the two worlds cannot
/// drift apart.
#[derive(Debug, Clone, Default)]
pub struct BlockedPairs {
    pairs: Vec<(NodeId, NodeId)>,
}

impl BlockedPairs {
    /// No partitions.
    pub fn new() -> BlockedPairs {
        BlockedPairs::default()
    }

    /// Block the unordered pair `(a, b)`.
    pub fn block(&mut self, a: NodeId, b: NodeId) {
        let pair = norm(a, b);
        if !self.pairs.contains(&pair) {
            self.pairs.push(pair);
        }
    }

    /// Block one group of nodes from another (cartesian product).
    pub fn block_groups(&mut self, left: &[NodeId], right: &[NodeId]) {
        for &a in left {
            for &b in right {
                self.block(a, b);
            }
        }
    }

    /// Unblock the unordered pair `(a, b)`.
    pub fn unblock(&mut self, a: NodeId, b: NodeId) {
        let pair = norm(a, b);
        self.pairs.retain(|&p| p != pair);
    }

    /// Unblock everything.
    pub fn clear(&mut self) {
        self.pairs.clear();
    }

    /// Is the unordered pair `(a, b)` blocked?
    pub fn contains(&self, a: NodeId, b: NodeId) -> bool {
        self.pairs.contains(&norm(a, b))
    }
}

/// Deterministic network model used by the discrete-event simulator.
#[derive(Debug, Clone)]
pub struct NetModel {
    cfg: NetConfig,
    rng: Rng,
    /// Active partitions.
    blocked: BlockedPairs,
    /// Runtime-injected extra loss, on top of the configured baseline
    /// (chaos schedules; see [`NetModel::degrade`]).
    extra_drop_prob: f64,
    /// Runtime-injected fixed extra one-way delay (µs).
    extra_delay_us: u64,
}

impl NetModel {
    /// Build from config with an independent RNG stream.
    pub fn new(cfg: NetConfig, rng: Rng) -> NetModel {
        NetModel {
            cfg,
            rng,
            blocked: BlockedPairs::new(),
            extra_drop_prob: 0.0,
            extra_delay_us: 0,
        }
    }

    /// Sample the one-way delay for a message, or `None` if it is dropped
    /// (random loss or active partition).
    ///
    /// Loopback (`from == to`) is exempt from *every* failure mode — a
    /// node always reaches its own store, even under a schedule that
    /// nominally partitions or degrades it. The early return makes that
    /// invariant structural instead of an accident of branch ordering.
    pub fn delay(&mut self, from: NodeId, to: NodeId) -> Option<u64> {
        if from == to {
            // local loopback: negligible but non-zero so event ordering
            // stays strict; never partitioned, dropped, or delayed
            return Some(1);
        }
        if self.is_partitioned(from, to) {
            return None;
        }
        if self.cfg.drop_prob > 0.0 && self.rng.chance(self.cfg.drop_prob) {
            return None;
        }
        if self.extra_drop_prob > 0.0 && self.rng.chance(self.extra_drop_prob) {
            return None;
        }
        let us = self.rng.exponential(self.cfg.mean_latency_us).max(1.0);
        Some(us as u64 + self.extra_delay_us)
    }

    /// Degrade link quality at runtime: `extra_drop_prob` is rolled *in
    /// addition to* the configured baseline loss, and `extra_delay_us` is
    /// added to every sampled remote delay. `(0.0, 0)` restores the
    /// configured baseline (the [`crate::sim::failure::Fault::Degrade`]
    /// semantics).
    pub fn degrade(&mut self, extra_drop_prob: f64, extra_delay_us: u64) {
        assert!((0.0..=1.0).contains(&extra_drop_prob));
        self.extra_drop_prob = extra_drop_prob;
        self.extra_delay_us = extra_delay_us;
    }

    /// Sample the client ⇄ proxy hop delay (never partitioned or dropped:
    /// clients retry transparently; the quorum machinery models
    /// availability).
    pub fn client_delay(&mut self) -> u64 {
        self.rng.exponential(self.cfg.mean_latency_us).max(1.0) as u64
    }

    /// Install a symmetric partition between `a` and `b`.
    pub fn partition(&mut self, a: NodeId, b: NodeId) {
        self.blocked.block(a, b);
    }

    /// Partition one group of nodes from another (cartesian product).
    pub fn partition_groups(&mut self, left: &[NodeId], right: &[NodeId]) {
        self.blocked.block_groups(left, right);
    }

    /// Heal a specific partition.
    pub fn heal(&mut self, a: NodeId, b: NodeId) {
        self.blocked.unblock(a, b);
    }

    /// Heal everything.
    pub fn heal_all(&mut self) {
        self.blocked.clear();
    }

    /// Is the pair currently partitioned?
    pub fn is_partitioned(&self, a: NodeId, b: NodeId) -> bool {
        self.blocked.contains(a, b)
    }

    /// Draw a per-client clock-skew offset (µs, may be negative) from the
    /// configured distribution. Called once per client at setup.
    pub fn draw_clock_skew(&mut self, _client: usize) -> i64 {
        if self.cfg.clock_skew_us == 0.0 {
            0
        } else {
            self.rng.normal(0.0, self.cfg.clock_skew_us) as i64
        }
    }
}

fn norm(a: NodeId, b: NodeId) -> (NodeId, NodeId) {
    if a <= b {
        (a, b)
    } else {
        (b, a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(drop: f64, skew: f64) -> NetModel {
        NetModel::new(
            NetConfig { mean_latency_us: 100.0, drop_prob: drop, clock_skew_us: skew },
            Rng::new(7),
        )
    }

    #[test]
    fn delays_are_positive_and_near_mean() {
        let mut m = model(0.0, 0.0);
        let n = 5000;
        let sum: u64 = (0..n).map(|_| m.delay(0, 1).unwrap()).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - 100.0).abs() < 10.0, "mean={mean}");
    }

    #[test]
    fn loopback_is_fast_and_lossless() {
        let mut m = model(1.0, 0.0); // 100% drop for remote
        for _ in 0..100 {
            assert_eq!(m.delay(2, 2), Some(1));
        }
    }

    #[test]
    fn loopback_is_never_partitioned_dropped_or_delayed() {
        // worst case on every axis: the node is "partitioned from
        // itself", baseline loss is total, and the link is degraded —
        // local delivery must still always succeed
        let mut m = model(1.0, 0.0);
        m.partition(2, 2);
        m.degrade(1.0, 10_000);
        for _ in 0..100 {
            assert_eq!(m.delay(2, 2), Some(1));
        }
        // remote traffic is meanwhile fully dropped
        assert_eq!(m.delay(0, 1), None);
    }

    #[test]
    fn degrade_adds_loss_and_delay_then_restores() {
        let mut m = model(0.0, 0.0);
        m.degrade(1.0, 0);
        assert_eq!(m.delay(0, 1), None, "degraded link drops everything");
        m.degrade(0.0, 2_000);
        let d = m.delay(0, 1).unwrap();
        assert!(d >= 2_000, "extra delay applied: {d}");
        m.degrade(0.0, 0);
        assert!(m.delay(0, 1).unwrap() < 2_000, "baseline restored");
    }

    #[test]
    fn drops_follow_probability() {
        let mut m = model(0.5, 0.0);
        let dropped = (0..4000).filter(|_| m.delay(0, 1).is_none()).count();
        assert!((1600..2400).contains(&dropped), "dropped={dropped}");
    }

    #[test]
    fn partitions_block_symmetrically_and_heal() {
        let mut m = model(0.0, 0.0);
        m.partition(0, 1);
        assert!(m.delay(0, 1).is_none());
        assert!(m.delay(1, 0).is_none());
        assert!(m.delay(0, 2).is_some());
        m.heal(1, 0);
        assert!(m.delay(0, 1).is_some());
    }

    #[test]
    fn group_partitions() {
        let mut m = model(0.0, 0.0);
        m.partition_groups(&[0, 1], &[2, 3]);
        assert!(m.is_partitioned(0, 2));
        assert!(m.is_partitioned(1, 3));
        assert!(!m.is_partitioned(0, 1));
        m.heal_all();
        assert!(!m.is_partitioned(0, 2));
    }

    #[test]
    fn skew_zero_when_disabled() {
        let mut m = model(0.0, 0.0);
        assert_eq!(m.draw_clock_skew(0), 0);
        let mut m2 = model(0.0, 5000.0);
        let skews: Vec<i64> = (0..50).map(|c| m2.draw_clock_skew(c)).collect();
        assert!(skews.iter().any(|&s| s != 0));
    }
}
