//! Simulated network: latency sampling, message drops, partitions, and
//! per-client wall-clock skew (for the §3.1 LWW anomaly).
//!
//! The model is intentionally simple and fully deterministic given a seed:
//! one-way delays are exponentially distributed around a configured mean;
//! partitions are symmetric sets of blocked node pairs; skew is a fixed
//! per-client offset drawn once from a normal distribution.

use crate::cluster::NodeId;
use crate::config::NetConfig;
use crate::testkit::Rng;

/// Deterministic network model used by the discrete-event simulator.
#[derive(Debug, Clone)]
pub struct NetModel {
    cfg: NetConfig,
    rng: Rng,
    /// Blocked unordered node pairs (active partitions).
    blocked: Vec<(NodeId, NodeId)>,
}

impl NetModel {
    /// Build from config with an independent RNG stream.
    pub fn new(cfg: NetConfig, rng: Rng) -> NetModel {
        NetModel { cfg, rng, blocked: Vec::new() }
    }

    /// Sample the one-way delay for a message, or `None` if it is dropped
    /// (random loss or active partition).
    pub fn delay(&mut self, from: NodeId, to: NodeId) -> Option<u64> {
        if from != to {
            if self.is_partitioned(from, to) {
                return None;
            }
            if self.cfg.drop_prob > 0.0 && self.rng.chance(self.cfg.drop_prob) {
                return None;
            }
        }
        if from == to {
            // local loopback: negligible but non-zero so event ordering
            // stays strict
            return Some(1);
        }
        let us = self.rng.exponential(self.cfg.mean_latency_us).max(1.0);
        Some(us as u64)
    }

    /// Sample the client ⇄ proxy hop delay (never partitioned or dropped:
    /// clients retry transparently; the quorum machinery models
    /// availability).
    pub fn client_delay(&mut self) -> u64 {
        self.rng.exponential(self.cfg.mean_latency_us).max(1.0) as u64
    }

    /// Install a symmetric partition between `a` and `b`.
    pub fn partition(&mut self, a: NodeId, b: NodeId) {
        let pair = norm(a, b);
        if !self.blocked.contains(&pair) {
            self.blocked.push(pair);
        }
    }

    /// Partition one group of nodes from another (cartesian product).
    pub fn partition_groups(&mut self, left: &[NodeId], right: &[NodeId]) {
        for &a in left {
            for &b in right {
                self.partition(a, b);
            }
        }
    }

    /// Heal a specific partition.
    pub fn heal(&mut self, a: NodeId, b: NodeId) {
        let pair = norm(a, b);
        self.blocked.retain(|&p| p != pair);
    }

    /// Heal everything.
    pub fn heal_all(&mut self) {
        self.blocked.clear();
    }

    /// Is the pair currently partitioned?
    pub fn is_partitioned(&self, a: NodeId, b: NodeId) -> bool {
        self.blocked.contains(&norm(a, b))
    }

    /// Draw a per-client clock-skew offset (µs, may be negative) from the
    /// configured distribution. Called once per client at setup.
    pub fn draw_clock_skew(&mut self, _client: usize) -> i64 {
        if self.cfg.clock_skew_us == 0.0 {
            0
        } else {
            self.rng.normal(0.0, self.cfg.clock_skew_us) as i64
        }
    }
}

fn norm(a: NodeId, b: NodeId) -> (NodeId, NodeId) {
    if a <= b {
        (a, b)
    } else {
        (b, a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(drop: f64, skew: f64) -> NetModel {
        NetModel::new(
            NetConfig { mean_latency_us: 100.0, drop_prob: drop, clock_skew_us: skew },
            Rng::new(7),
        )
    }

    #[test]
    fn delays_are_positive_and_near_mean() {
        let mut m = model(0.0, 0.0);
        let n = 5000;
        let sum: u64 = (0..n).map(|_| m.delay(0, 1).unwrap()).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - 100.0).abs() < 10.0, "mean={mean}");
    }

    #[test]
    fn loopback_is_fast_and_lossless() {
        let mut m = model(1.0, 0.0); // 100% drop for remote
        for _ in 0..100 {
            assert_eq!(m.delay(2, 2), Some(1));
        }
    }

    #[test]
    fn drops_follow_probability() {
        let mut m = model(0.5, 0.0);
        let dropped = (0..4000).filter(|_| m.delay(0, 1).is_none()).count();
        assert!((1600..2400).contains(&dropped), "dropped={dropped}");
    }

    #[test]
    fn partitions_block_symmetrically_and_heal() {
        let mut m = model(0.0, 0.0);
        m.partition(0, 1);
        assert!(m.delay(0, 1).is_none());
        assert!(m.delay(1, 0).is_none());
        assert!(m.delay(0, 2).is_some());
        m.heal(1, 0);
        assert!(m.delay(0, 1).is_some());
    }

    #[test]
    fn group_partitions() {
        let mut m = model(0.0, 0.0);
        m.partition_groups(&[0, 1], &[2, 3]);
        assert!(m.is_partitioned(0, 2));
        assert!(m.is_partitioned(1, 3));
        assert!(!m.is_partitioned(0, 1));
        m.heal_all();
        assert!(!m.is_partitioned(0, 2));
    }

    #[test]
    fn skew_zero_when_disabled() {
        let mut m = model(0.0, 0.0);
        assert_eq!(m.draw_clock_skew(0), 0);
        let mut m2 = model(0.0, 5000.0);
        let skews: Vec<i64> = (0..50).map(|c| m2.draw_clock_skew(c)).collect();
        assert!(skews.iter().any(|&s| s != 0));
    }
}
