//! Coordinator logic for quorum get/put (§4.1, Figures 5–6), as pure
//! state machines reusable by both the discrete-event simulator
//! ([`crate::sim`]) and the threaded TCP server ([`crate::server`]).
//!
//! * GET: fan out to the key's replicas, reduce replies with the
//!   mechanism's `merge` (the paper's `sync`), answer after `R` replies,
//!   then optionally read-repair stale replicas with the merged state.
//! * PUT: apply the mechanism's `update`+`sync` at the coordinator,
//!   replicate the resulting state, answer after `W` acknowledgements.
//! * Replication fan-out accumulates per-peer `(key, state)` payloads in
//!   a [`MergeBatch`] so the store layer can apply each peer's batch with
//!   one lock round ([`crate::store::KeyStore::merge_batch`]) instead of
//!   one merge call per key.

use crate::kernel::{Mechanism, Val};
use crate::store::Key;

/// Quorum parameters `(N, R, W)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuorumSpec {
    /// Replication degree.
    pub n: usize,
    /// Read quorum.
    pub r: usize,
    /// Write quorum.
    pub w: usize,
}

impl QuorumSpec {
    /// Construct and sanity-check.
    pub fn new(n: usize, r: usize, w: usize) -> crate::Result<QuorumSpec> {
        if n == 0 || r == 0 || w == 0 || r > n || w > n {
            return Err(crate::Error::Config(format!(
                "invalid quorum (N={n}, R={r}, W={w})"
            )));
        }
        Ok(QuorumSpec { n, r, w })
    }

    /// Does `R + W > N` (read-your-writes intersection)?
    pub fn intersecting(&self) -> bool {
        self.r + self.w > self.n
    }
}

/// In-flight GET at a coordinator.
#[derive(Debug, Clone)]
pub struct GetOp<M: Mechanism> {
    merged: M::State,
    replies: usize,
    spec: QuorumSpec,
    answered: bool,
}

/// Result of a completed GET quorum.
#[derive(Debug, Clone)]
pub struct GetResult<M: Mechanism> {
    /// Live sibling values.
    pub values: Vec<Val>,
    /// The causal context for subsequent PUTs.
    pub context: M::Context,
    /// The reduced state (for read repair).
    pub merged: M::State,
}

impl<M: Mechanism> GetOp<M> {
    /// Start a GET under the given quorum spec.
    pub fn new(spec: QuorumSpec) -> GetOp<M> {
        GetOp { merged: M::State::default(), replies: 0, spec, answered: false }
    }

    /// Feed one replica reply. Returns the client answer when the read
    /// quorum is first reached (later replies keep folding into `merged`
    /// for read repair but return `None`).
    pub fn on_reply(&mut self, mech: &M, state: &M::State) -> Option<GetResult<M>> {
        mech.merge(&mut self.merged, state);
        self.replies += 1;
        if self.replies == self.spec.r && !self.answered {
            self.answered = true;
            let (values, context) = mech.read(&self.merged);
            Some(GetResult { values, context, merged: self.merged.clone() })
        } else {
            None
        }
    }

    /// Replies received so far.
    pub fn replies(&self) -> usize {
        self.replies
    }

    /// Has the quorum answered?
    pub fn answered(&self) -> bool {
        self.answered
    }

    /// Current merged state (read repair after all replies arrive).
    pub fn merged(&self) -> &M::State {
        &self.merged
    }
}

/// In-flight PUT at a coordinator (after the local write succeeded —
/// the coordinator's own store counts as the first ack).
///
/// The ack source is the caller's concern: the simulator feeds
/// `ReplicateAck` messages, and the threaded cluster's *sloppy quorum*
/// also counts acknowledgements from stand-in nodes holding hinted
/// writes for unreachable home replicas
/// ([`crate::server::LocalCluster::put_traced`]) — `PutOp` only cares
/// that `W` distinct nodes acknowledged.
#[derive(Debug, Clone)]
pub struct PutOp {
    acks: usize,
    spec: QuorumSpec,
    answered: bool,
}

impl PutOp {
    /// Start a PUT; `acks` starts at 1 for the coordinator's local write.
    pub fn new(spec: QuorumSpec) -> PutOp {
        PutOp { acks: 1, spec, answered: false }
    }

    /// Feed one replica acknowledgement; true when the write quorum is
    /// first satisfied.
    pub fn on_ack(&mut self) -> bool {
        self.acks += 1;
        if self.acks >= self.spec.w && !self.answered {
            self.answered = true;
            true
        } else {
            false
        }
    }

    /// Is the write quorum already satisfied by the local write alone?
    pub fn satisfied_immediately(&mut self) -> bool {
        if self.acks >= self.spec.w && !self.answered {
            self.answered = true;
            true
        } else {
            false
        }
    }

    /// Acks so far.
    pub fn acks(&self) -> usize {
        self.acks
    }
}

/// Per-peer accumulation of `(key, state)` replication payloads.
///
/// Both the PUT fan-out (§4.1 put step 4) and anti-entropy exchanges push
/// merges here instead of calling the destination store once per key; a
/// drained peer batch is applied through
/// [`KeyStore::merge_batch`](crate::store::KeyStore::merge_batch), which
/// takes each backend stripe lock at most once per batch.
#[derive(Debug, Clone)]
pub struct MergeBatch<M: Mechanism> {
    peers: Vec<Vec<(Key, M::State)>>,
}

impl<M: Mechanism> MergeBatch<M> {
    /// Empty batch addressing `peer_count` peers (dense peer ids).
    pub fn new(peer_count: usize) -> MergeBatch<M> {
        MergeBatch { peers: (0..peer_count).map(|_| Vec::new()).collect() }
    }

    /// Queue `state` to be merged into `key` at `peer`.
    pub fn push(&mut self, peer: usize, key: Key, state: M::State) {
        self.peers[peer].push((key, state));
    }

    /// Number of payloads queued for `peer`.
    pub fn pending(&self, peer: usize) -> usize {
        self.peers[peer].len()
    }

    /// Total payloads queued across peers.
    pub fn len(&self) -> usize {
        self.peers.iter().map(Vec::len).sum()
    }

    /// Is the batch empty?
    pub fn is_empty(&self) -> bool {
        self.peers.iter().all(Vec::is_empty)
    }

    /// Drain the batch as `(peer, payloads)` groups, skipping idle peers.
    pub fn drain(&mut self) -> impl Iterator<Item = (usize, Vec<(Key, M::State)>)> + '_ {
        self.peers
            .iter_mut()
            .enumerate()
            .filter(|(_, items)| !items.is_empty())
            .map(|(peer, items)| (peer, std::mem::take(items)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::Actor;
    use crate::kernel::mechs::DvvMech;
    use crate::kernel::WriteMeta;

    #[test]
    fn quorum_validation() {
        assert!(QuorumSpec::new(3, 2, 2).unwrap().intersecting());
        assert!(!QuorumSpec::new(3, 1, 1).unwrap().intersecting());
        assert!(QuorumSpec::new(3, 4, 1).is_err());
        assert!(QuorumSpec::new(0, 0, 0).is_err());
    }

    #[test]
    fn get_answers_at_r_and_keeps_merging() {
        let mech = DvvMech;
        let spec = QuorumSpec::new(3, 2, 2).unwrap();
        let mut op: GetOp<DvvMech> = GetOp::new(spec);

        // replica 1 has a sibling; replica 2 empty; replica 3 has another
        let mut s1 = Vec::new();
        mech.write(&mut s1, &Default::default(), Val::new(1, 0), Actor::server(0), &WriteMeta::basic(Actor::client(0)));
        let mut s3 = Vec::new();
        mech.write(&mut s3, &Default::default(), Val::new(2, 0), Actor::server(2), &WriteMeta::basic(Actor::client(1)));

        assert!(op.on_reply(&mech, &s1).is_none());
        let res = op.on_reply(&mech, &Vec::new()).expect("answer at R=2");
        assert_eq!(res.values, vec![Val::new(1, 0)]);
        // third reply folds in for read repair but does not answer again
        assert!(op.on_reply(&mech, &s3).is_none());
        assert_eq!(mech.values(op.merged()).len(), 2);
        assert_eq!(op.replies(), 3);
    }

    #[test]
    fn put_quorum_counts_local_write() {
        let spec = QuorumSpec::new(3, 2, 2).unwrap();
        let mut op = PutOp::new(spec);
        assert!(!op.satisfied_immediately());
        assert!(op.on_ack(), "W=2 reached with coordinator + 1 ack");
        assert!(!op.on_ack(), "already answered");
        assert_eq!(op.acks(), 3);
    }

    #[test]
    fn put_w1_satisfied_by_local_write() {
        let spec = QuorumSpec::new(3, 1, 1).unwrap();
        let mut op = PutOp::new(spec);
        assert!(op.satisfied_immediately());
    }

    #[test]
    fn merge_batch_groups_per_peer() {
        let mut b: MergeBatch<DvvMech> = MergeBatch::new(3);
        assert!(b.is_empty());
        b.push(0, 1, Vec::new());
        b.push(2, 1, Vec::new());
        b.push(2, 7, Vec::new());
        assert_eq!(b.len(), 3);
        assert_eq!(b.pending(2), 2);
        assert_eq!(b.pending(1), 0);
        let groups: Vec<_> = b.drain().collect();
        assert_eq!(groups.len(), 2, "idle peer 1 skipped");
        assert_eq!(groups[0].0, 0);
        assert_eq!(groups[1].0, 2);
        assert_eq!(groups[1].1, vec![(1, Vec::new()), (7, Vec::new())]);
        assert!(b.is_empty(), "drain leaves the batch reusable");
    }
}
