//! Clock-tensor packing: the rust side of the shared encoding contract
//! (DESIGN.md §2, mirrored by `python/compile/kernels/ref.py`).
//!
//! A clock row is `i32[R + 2]`: `R` per-slot contiguous range maxima, a
//! dot slot index (`-1` = none), and the dot event number. Slot indices
//! come from a caller-supplied [`SlotMap`] from replica [`Actor`]s.

use std::collections::BTreeMap;

use crate::clocks::{Actor, ClockOrd, Dvv, LogicalClock};
use crate::error::{Error, Result};

/// Maps replica actors to tensor slots `0..R`.
#[derive(Debug, Clone, Default)]
pub struct SlotMap {
    slots: BTreeMap<Actor, usize>,
}

impl SlotMap {
    /// Empty map.
    pub fn new() -> SlotMap {
        SlotMap::default()
    }

    /// Dense map over the first `r` server actors.
    pub fn dense(r: usize) -> SlotMap {
        let mut m = SlotMap::new();
        for i in 0..r {
            m.slots.insert(Actor::server(i as u32), i);
        }
        m
    }

    /// Slot of `actor`, registering it if new.
    pub fn intern(&mut self, actor: Actor) -> usize {
        let next = self.slots.len();
        *self.slots.entry(actor).or_insert(next)
    }

    /// Slot of `actor`, if registered.
    pub fn get(&self, actor: Actor) -> Option<usize> {
        self.slots.get(&actor).copied()
    }

    /// Number of registered actors.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Is the map empty?
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Build from every actor mentioned in a clock list.
    pub fn from_clocks<'a, I: IntoIterator<Item = &'a Dvv>>(clocks: I) -> SlotMap {
        let mut m = SlotMap::new();
        for c in clocks {
            for (a, _) in c.vv.iter() {
                m.intern(a);
            }
            if let Some((a, _)) = c.dot {
                m.intern(a);
            }
        }
        m
    }
}

/// Encode one clock as a row of width `r + 2`.
pub fn encode_row(clock: &Dvv, slots: &SlotMap, r: usize, out: &mut Vec<i32>) -> Result<()> {
    let base = out.len();
    out.resize(base + r + 2, 0);
    out[base + r] = -1;
    for (actor, n) in clock.vv.iter() {
        let slot = slots
            .get(actor)
            .ok_or_else(|| Error::Artifact(format!("actor {actor} not in slot map")))?;
        if slot >= r {
            return Err(Error::Artifact(format!(
                "slot {slot} exceeds encoded width R={r}"
            )));
        }
        out[base + slot] = i32::try_from(n)
            .map_err(|_| Error::Artifact(format!("counter {n} exceeds i32")))?;
    }
    if let Some((actor, n)) = clock.dot {
        let slot = slots
            .get(actor)
            .ok_or_else(|| Error::Artifact(format!("dot actor {actor} not in slot map")))?;
        if slot >= r {
            return Err(Error::Artifact(format!("dot slot {slot} exceeds R={r}")));
        }
        out[base + r] = slot as i32;
        out[base + r + 1] = i32::try_from(n)
            .map_err(|_| Error::Artifact(format!("dot {n} exceeds i32")))?;
    }
    Ok(())
}

/// Pack a clock batch into a padded row-major `i32[pad_to, r+2]` tensor.
/// Pad rows are empty clocks (all-zero vv, dot slot -1).
pub fn pack(clocks: &[Dvv], slots: &SlotMap, r: usize, pad_to: usize) -> Result<Vec<i32>> {
    if clocks.len() > pad_to {
        return Err(Error::Artifact(format!(
            "batch {} exceeds padded size {pad_to}",
            clocks.len()
        )));
    }
    let mut out = Vec::with_capacity(pad_to * (r + 2));
    for c in clocks {
        encode_row(c, slots, r, &mut out)?;
    }
    for _ in clocks.len()..pad_to {
        let base = out.len();
        out.resize(base + r + 2, 0);
        out[base + r] = -1;
    }
    Ok(out)
}

/// Scalar mirror of the kernel's dominance code for one pair — used to
/// cross-check the XLA path (tests + `debug_assert` sampling).
pub fn dominance_code(a: &Dvv, b: &Dvv) -> i32 {
    match a.compare(b) {
        ClockOrd::Concurrent => 0,
        ClockOrd::Less => 1,
        ClockOrd::Greater => 2,
        ClockOrd::Equal => 3,
    }
}

/// Scalar mirror of the full `a.len() × b.len()` dominance-code matrix
/// (row-major) that [`crate::runtime::XlaEngine::dominance_codes`]
/// produces — the contract the block-diagonal multi-key reduction in
/// [`crate::antientropy::sync_xla`] consumes. Used to cross-check the
/// XLA path and as its drop-in fallback in environments without
/// artifacts.
pub fn dominance_codes_scalar(a: &[Dvv], b: &[Dvv]) -> Vec<i32> {
    let mut codes = Vec::with_capacity(a.len() * b.len());
    for x in a {
        for y in b {
            codes.push(dominance_code(x, y));
        }
    }
    codes
}

/// Scalar reference of the bulk-sync keep-masks (identical reduction to
/// `python/compile/model.py::bulk_sync`).
pub fn bulk_sync_scalar(a: &[Dvv], b: &[Dvv]) -> (Vec<bool>, Vec<bool>) {
    let keep_a = a
        .iter()
        .map(|x| !b.iter().any(|y| x.compare(y) == ClockOrd::Less))
        .collect();
    let keep_b = b
        .iter()
        .map(|y| !a.iter().any(|x| y.compare(x).is_leq()))
        .collect();
    (keep_a, keep_b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::dvv::dvv;

    fn a() -> Actor {
        Actor::server(0)
    }
    fn b() -> Actor {
        Actor::server(1)
    }

    #[test]
    fn row_layout_matches_contract() {
        let slots = SlotMap::dense(4);
        let c = dvv(&[(a(), 2), (b(), 1)], Some((b(), 3)));
        let mut out = Vec::new();
        encode_row(&c, &slots, 4, &mut out).unwrap();
        assert_eq!(out, vec![2, 1, 0, 0, /*dot slot*/ 1, /*dot n*/ 3]);
    }

    #[test]
    fn dotless_row_has_sentinel() {
        let slots = SlotMap::dense(2);
        let c = dvv(&[(a(), 5)], None);
        let mut out = Vec::new();
        encode_row(&c, &slots, 2, &mut out).unwrap();
        assert_eq!(out, vec![5, 0, -1, 0]);
    }

    #[test]
    fn pack_pads_with_empty_rows() {
        let slots = SlotMap::dense(2);
        let clocks = vec![dvv(&[(a(), 1)], None)];
        let t = pack(&clocks, &slots, 2, 3).unwrap();
        assert_eq!(t.len(), 3 * 4);
        assert_eq!(&t[4..8], &[0, 0, -1, 0]);
        assert_eq!(&t[8..12], &[0, 0, -1, 0]);
    }

    #[test]
    fn pack_rejects_overflow_batch() {
        let slots = SlotMap::dense(2);
        let clocks = vec![dvv(&[], Some((a(), 1))); 5];
        assert!(pack(&clocks, &slots, 2, 4).is_err());
    }

    #[test]
    fn unknown_actor_is_an_error() {
        let slots = SlotMap::dense(1); // only server 0
        let c = dvv(&[(b(), 1)], None);
        let mut out = Vec::new();
        assert!(encode_row(&c, &slots, 1, &mut out).is_err());
    }

    #[test]
    fn slotmap_interning_is_stable() {
        let mut m = SlotMap::new();
        assert_eq!(m.intern(b()), 0);
        assert_eq!(m.intern(a()), 1);
        assert_eq!(m.intern(b()), 0, "re-intern returns the same slot");
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn scalar_code_matrix_is_row_major_and_complete() {
        let s1 = vec![dvv(&[], Some((a(), 1))), dvv(&[(a(), 2)], None)];
        let s2 = vec![dvv(&[], Some((a(), 1))), dvv(&[], Some((b(), 1)))];
        let codes = dominance_codes_scalar(&s1, &s2);
        assert_eq!(codes.len(), 4);
        assert_eq!(codes[0], 3, "identical clocks compare equal");
        assert_eq!(codes[1], 0, "dots of different actors are concurrent");
        assert_eq!(codes[2], 2, "row-major: [(a,2)] dominates the a-dot");
        assert_eq!(codes[3], 0);
        assert!(dominance_codes_scalar(&s1, &[]).is_empty());
    }

    #[test]
    fn scalar_bulk_sync_matches_kernel_semantics() {
        // concurrent pair: both kept; dominated pair: loser dropped
        let s1 = vec![dvv(&[], Some((a(), 1)))];
        let s2 = vec![dvv(&[], Some((b(), 1)))];
        assert_eq!(bulk_sync_scalar(&s1, &s2), (vec![true], vec![true]));
        let s3 = vec![dvv(&[(a(), 1)], Some((b(), 1)))];
        assert_eq!(bulk_sync_scalar(&s1, &s3), (vec![false], vec![true]));
        // equal keeps the A copy
        assert_eq!(bulk_sync_scalar(&s1, &s1.clone()), (vec![true], vec![false]));
    }
}
