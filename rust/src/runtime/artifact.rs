//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. One line per shape-specialized HLO module:
//!
//! ```text
//! <kind> <name> <N> <M> <R> <file>
//! ```
//!
//! The runtime picks the smallest variant that fits a request and pads
//! inputs up to its shape.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// One AOT-compiled module variant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Artifact {
    /// Graph kind: `bulk_sync` or `vv_merge`.
    pub kind: String,
    /// Unique name, e.g. `bulk_sync_256x256_r8`.
    pub name: String,
    /// First batch dimension.
    pub n: usize,
    /// Second batch dimension (equals `n` for `vv_merge`).
    pub m: usize,
    /// Replica-slot count baked into the clock encoding.
    pub r: usize,
    /// HLO text file, absolute.
    pub path: PathBuf,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// All artifacts, as listed.
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    /// Parse manifest text; `dir` anchors relative file names.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 6 {
                return Err(Error::Artifact(format!(
                    "manifest line {}: expected 6 fields, got {}",
                    lineno + 1,
                    parts.len()
                )));
            }
            let parse_usize = |s: &str, what: &str| {
                s.parse::<usize>().map_err(|_| {
                    Error::Artifact(format!("manifest line {}: bad {what} {s:?}", lineno + 1))
                })
            };
            artifacts.push(Artifact {
                kind: parts[0].to_string(),
                name: parts[1].to_string(),
                n: parse_usize(parts[2], "N")?,
                m: parse_usize(parts[3], "M")?,
                r: parse_usize(parts[4], "R")?,
                path: dir.join(parts[5]),
            });
        }
        Ok(Manifest { artifacts })
    }

    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {path:?} (run `make artifacts` first): {e}"
            ))
        })?;
        Manifest::parse(&text, dir)
    }

    /// Smallest `bulk_sync` variant fitting `n × m` clocks with `r` slots.
    pub fn pick_bulk_sync(&self, n: usize, m: usize, r: usize) -> Option<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == "bulk_sync" && a.n >= n && a.m >= m && a.r >= r)
            .min_by_key(|a| a.n * a.m)
    }

    /// Smallest `vv_merge` variant fitting `b` vectors with `r` slots.
    pub fn pick_vv_merge(&self, b: usize, r: usize) -> Option<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == "vv_merge" && a.n >= b && a.r >= r)
            .min_by_key(|a| a.n)
    }
}

/// Default artifacts directory: `$DVV_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("DVV_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
bulk_sync bulk_sync_64x64_r8 64 64 8 bulk_sync_64x64_r8.hlo.txt
bulk_sync bulk_sync_256x256_r8 256 256 8 bulk_sync_256x256_r8.hlo.txt
vv_merge vv_merge_1024_r8 1024 1024 8 vv_merge_1024_r8.hlo.txt
";

    #[test]
    fn parses_and_anchors_paths() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.artifacts[0].n, 64);
        assert_eq!(m.artifacts[0].path, Path::new("/art/bulk_sync_64x64_r8.hlo.txt"));
    }

    #[test]
    fn picks_smallest_fitting_variant() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.pick_bulk_sync(10, 10, 8).unwrap().n, 64);
        assert_eq!(m.pick_bulk_sync(64, 64, 8).unwrap().n, 64);
        assert_eq!(m.pick_bulk_sync(65, 10, 8).unwrap().n, 256);
        assert!(m.pick_bulk_sync(300, 300, 8).is_none());
        assert!(m.pick_bulk_sync(10, 10, 16).is_none(), "r too large");
        assert_eq!(m.pick_vv_merge(500, 8).unwrap().name, "vv_merge_1024_r8");
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("too few fields", Path::new("/a")).is_err());
        assert!(Manifest::parse("k n x y z f", Path::new("/a")).is_err());
        // comments and blanks are fine
        let ok = Manifest::parse("# comment\n\n", Path::new("/a")).unwrap();
        assert!(ok.artifacts.is_empty());
    }
}
