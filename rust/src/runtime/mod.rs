//! PJRT runtime bridge: load the AOT-compiled XLA artifacts (HLO text,
//! emitted once by `make artifacts` from JAX/Pallas) and execute them from
//! the rust hot path. Python never runs here.
//!
//! The interchange format is HLO **text** — the image's xla_extension
//! 0.5.1 rejects serialized protos from jax ≥ 0.5 (64-bit instruction
//! ids); `HloModuleProto::from_text_file` re-parses and reassigns ids.

pub mod artifact;
pub mod batch;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::clocks::Dvv;
use crate::error::{Error, Result};
use artifact::{Artifact, Manifest};
use batch::SlotMap;

/// Result of a bulk `sync` over two clock batches.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BulkSyncResult {
    /// Keep-mask for the first batch.
    pub keep_a: Vec<bool>,
    /// Keep-mask for the second batch.
    pub keep_b: Vec<bool>,
}

/// A PJRT CPU engine holding compiled executables for every artifact
/// variant (compiled lazily, cached thereafter).
pub struct XlaEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl std::fmt::Debug for XlaEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaEngine")
            .field("dir", &self.dir)
            .field("artifacts", &self.manifest.artifacts.len())
            .field("compiled", &self.compiled.len())
            .finish()
    }
}

impl XlaEngine {
    /// Open the engine over an artifacts directory (see
    /// [`artifact::default_dir`]).
    pub fn open(dir: &Path) -> Result<XlaEngine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(XlaEngine {
            client,
            manifest,
            compiled: HashMap::new(),
            dir: dir.to_path_buf(),
        })
    }

    /// Open at the default location.
    pub fn open_default() -> Result<XlaEngine> {
        XlaEngine::open(&artifact::default_dir())
    }

    /// Artifact inventory.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&mut self, art: &Artifact) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(&art.name) {
            let proto = xla::HloModuleProto::from_text_file(&art.path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.compiled.insert(art.name.clone(), exe);
        }
        Ok(&self.compiled[&art.name])
    }

    /// Warm the compile cache for every artifact (start-up, benches).
    pub fn compile_all(&mut self) -> Result<usize> {
        let arts = self.manifest.artifacts.clone();
        for art in &arts {
            self.executable(art)?;
        }
        Ok(arts.len())
    }

    /// The paper's `sync(S1, S2)` keep-masks over two DVV batches,
    /// computed by the AOT-compiled Pallas dominance kernel.
    ///
    /// `slots` maps replica actors to tensor slots; every actor in either
    /// batch must fit inside the variant's `R`. Empty clocks must not
    /// appear (versions always carry at least a dot).
    pub fn bulk_sync(
        &mut self,
        a: &[Dvv],
        b: &[Dvv],
        slots: &SlotMap,
    ) -> Result<BulkSyncResult> {
        let art = self
            .manifest
            .pick_bulk_sync(a.len(), b.len(), slots.len())
            .cloned()
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "no bulk_sync variant fits {}x{} r={}",
                    a.len(),
                    b.len(),
                    slots.len()
                ))
            })?;
        let r = art.r;
        let ta = batch::pack(a, slots, r, art.n)?;
        let tb = batch::pack(b, slots, r, art.m)?;
        let w = (r + 2) as i64;
        let la = xla::Literal::vec1(&ta).reshape(&[art.n as i64, w])?;
        let lb = xla::Literal::vec1(&tb).reshape(&[art.m as i64, w])?;
        let exe = self.executable(&art)?;
        let result = exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (keep_a, keep_b, codes)
        let (keep_a_lit, keep_b_lit, _codes) = result.to_tuple3()?;
        let keep_a_raw = keep_a_lit.to_vec::<i32>()?;
        let keep_b_raw = keep_b_lit.to_vec::<i32>()?;
        Ok(BulkSyncResult {
            keep_a: keep_a_raw[..a.len()].iter().map(|&x| x != 0).collect(),
            keep_b: keep_b_raw[..b.len()].iter().map(|&x| x != 0).collect(),
        })
    }

    /// Full dominance-code matrix for two DVV batches (row-major
    /// `a.len() × b.len()`, codes `0`=concurrent `1`=less `2`=greater
    /// `3`=equal). Used by the multi-key anti-entropy path, which needs
    /// per-block reductions rather than whole-batch keep-masks (clocks of
    /// *different keys* must never dominate each other — see
    /// `antientropy::sync_xla`).
    pub fn dominance_codes(
        &mut self,
        a: &[Dvv],
        b: &[Dvv],
        slots: &SlotMap,
    ) -> Result<Vec<i32>> {
        let art = self
            .manifest
            .pick_bulk_sync(a.len(), b.len(), slots.len())
            .cloned()
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "no bulk_sync variant fits {}x{} r={}",
                    a.len(),
                    b.len(),
                    slots.len()
                ))
            })?;
        let r = art.r;
        let ta = batch::pack(a, slots, r, art.n)?;
        let tb = batch::pack(b, slots, r, art.m)?;
        let w = (r + 2) as i64;
        let la = xla::Literal::vec1(&ta).reshape(&[art.n as i64, w])?;
        let lb = xla::Literal::vec1(&tb).reshape(&[art.m as i64, w])?;
        let exe = self.executable(&art)?;
        let result = exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
        let (_keep_a, _keep_b, codes_lit) = result.to_tuple3()?;
        let padded = codes_lit.to_vec::<i32>()?;
        // slice the [0..a.len(), 0..b.len()] sub-block out of art.n × art.m
        let mut codes = Vec::with_capacity(a.len() * b.len());
        for i in 0..a.len() {
            let row = &padded[i * art.m..i * art.m + b.len()];
            codes.extend_from_slice(row);
        }
        Ok(codes)
    }

    /// Pointwise version-vector join of two equal-shaped `i32[b, r]`
    /// batches via the `vv_merge` artifact. Inputs are row-major.
    pub fn vv_merge(&mut self, a: &[i32], b: &[i32], r: usize) -> Result<Vec<i32>> {
        if a.len() != b.len() || a.len() % r != 0 {
            return Err(Error::Artifact(format!(
                "vv_merge shape mismatch: {} vs {} (r={r})",
                a.len(),
                b.len()
            )));
        }
        let rows = a.len() / r;
        let art = self
            .manifest
            .pick_vv_merge(rows, r)
            .cloned()
            .ok_or_else(|| Error::Artifact(format!("no vv_merge variant fits {rows} r={r}")))?;
        let mut ta = a.to_vec();
        let mut tb = b.to_vec();
        ta.resize(art.n * art.r, 0);
        tb.resize(art.n * art.r, 0);
        let la = xla::Literal::vec1(&ta).reshape(&[art.n as i64, art.r as i64])?;
        let lb = xla::Literal::vec1(&tb).reshape(&[art.n as i64, art.r as i64])?;
        let exe = self.executable(&art)?;
        let result = exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
        let merged = result.to_tuple1()?.to_vec::<i32>()?;
        Ok(merged[..a.len()].to_vec())
    }
}

#[cfg(test)]
mod tests {
    //! These tests require `make artifacts` to have run; they are skipped
    //! (not failed) when the artifacts directory is absent so `cargo test`
    //! works on a fresh checkout.

    use super::*;
    use crate::clocks::dvv::dvv;
    use crate::clocks::Actor;
    use crate::testkit::Rng;

    fn engine() -> Option<XlaEngine> {
        let dir = artifact::default_dir();
        if dir.join("manifest.txt").exists() {
            Some(XlaEngine::open(&dir).expect("engine opens"))
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    fn a() -> Actor {
        Actor::server(0)
    }
    fn b() -> Actor {
        Actor::server(1)
    }

    #[test]
    fn bulk_sync_matches_scalar_reference() {
        let Some(mut eng) = engine() else { return };
        let slots = SlotMap::dense(4);
        let s1 = vec![
            dvv(&[], Some((a(), 1))),
            dvv(&[(a(), 1)], Some((b(), 1))),
            dvv(&[(a(), 4)], None),
        ];
        let s2 = vec![
            dvv(&[(a(), 3)], Some((a(), 5))),
            dvv(&[], Some((b(), 1))),
        ];
        let got = eng.bulk_sync(&s1, &s2, &slots).unwrap();
        let (keep_a, keep_b) = batch::bulk_sync_scalar(&s1, &s2);
        assert_eq!(got.keep_a, keep_a);
        assert_eq!(got.keep_b, keep_b);
    }

    #[test]
    fn bulk_sync_randomized_against_scalar() {
        let Some(mut eng) = engine() else { return };
        let slots = SlotMap::dense(8);
        let mut rng = Rng::new(31);
        for _ in 0..10 {
            let gen_batch = |rng: &mut Rng| -> Vec<Dvv> {
                (0..rng.range(1, 20))
                    .map(|_| {
                        let vvp = crate::clocks::VersionVector::from_pairs(
                            (0..8u32).map(|i| (Actor::server(i), rng.below(4))),
                        );
                        let r = Actor::server(rng.below(8) as u32);
                        let n = vvp.get(r) + 1 + rng.below(3);
                        Dvv { vv: vvp, dot: Some((r, n)) }
                    })
                    .collect()
            };
            let s1 = gen_batch(&mut rng);
            let s2 = gen_batch(&mut rng);
            let got = eng.bulk_sync(&s1, &s2, &slots).unwrap();
            let (keep_a, keep_b) = batch::bulk_sync_scalar(&s1, &s2);
            assert_eq!(got.keep_a, keep_a, "s1={s1:?} s2={s2:?}");
            assert_eq!(got.keep_b, keep_b, "s1={s1:?} s2={s2:?}");
        }
    }

    #[test]
    fn vv_merge_is_pointwise_max() {
        let Some(mut eng) = engine() else { return };
        let r = 8;
        let x: Vec<i32> = (0..64).collect();
        let y: Vec<i32> = (0..64).rev().collect();
        let m = eng.vv_merge(&x, &y, r).unwrap();
        for i in 0..64 {
            assert_eq!(m[i], x[i].max(y[i]));
        }
    }

    #[test]
    fn variant_selection_errors_when_too_big() {
        let Some(mut eng) = engine() else { return };
        let slots = SlotMap::dense(2);
        let huge = vec![dvv(&[], Some((a(), 1))); 5000];
        assert!(eng.bulk_sync(&huge, &huge, &slots).is_err());
    }
}
