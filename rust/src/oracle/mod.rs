//! Ground-truth causality oracle.
//!
//! Runs beside any mechanism under test and tracks the *true* causal
//! history of every written value. Because each client is sequential, true
//! causality is exactly representable as a version vector over client
//! actors (per key) — the §3.3 observation that per-client entries match
//! the sources of concurrency. The oracle uses this to classify every
//! version the mechanism discards as either a **correct supersession**
//! (the surviving value causally covers it) or a **lost update** (it does
//! not), and every sibling pair returned by a GET as **truly concurrent**
//! or **falsely concurrent**.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::clocks::{Actor, VersionVector};
use crate::kernel::Val;
use crate::store::Key;

/// Verdict for one discarded value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropVerdict {
    /// Some surviving value causally dominates the dropped one.
    CorrectSupersession,
    /// No survivor covers it: a concurrent update was destroyed.
    LostUpdate,
}

/// The ground-truth tracker.
#[derive(Debug, Clone, Default)]
pub struct Oracle {
    /// True history of each value id, as a client-indexed version vector.
    hist: HashMap<u64, VersionVector>,
    /// Per (client, key) sequential write counters.
    counters: HashMap<(Actor, Key), u64>,
}

impl Oracle {
    /// New empty oracle.
    pub fn new() -> Oracle {
        Oracle::default()
    }

    /// Register a write: `client` wrote value `val_id` to `key`, having
    /// last observed the values in `observed` (ids from its latest GET of
    /// this key, empty for a blind write). Returns the true history
    /// assigned to the new value.
    pub fn on_write(
        &mut self,
        client: Actor,
        key: Key,
        val_id: u64,
        observed: &[u64],
    ) -> VersionVector {
        let mut vv = VersionVector::new();
        for id in observed {
            if let Some(h) = self.hist.get(id) {
                vv.join_from(h);
            }
        }
        let counter = self.counters.entry((client, key)).or_insert(0);
        *counter += 1;
        vv.set(client, *counter);
        self.hist.insert(val_id, vv.clone());
        vv
    }

    /// True history of a value (empty when unknown).
    pub fn history_of(&self, val_id: u64) -> VersionVector {
        self.hist.get(&val_id).cloned().unwrap_or_default()
    }

    /// Does value `a` causally precede-or-equal value `b`?
    pub fn leq(&self, a: u64, b: u64) -> bool {
        match (self.hist.get(&a), self.hist.get(&b)) {
            (Some(ha), Some(hb)) => ha.dominated_by(hb),
            _ => false,
        }
    }

    /// Are values `a` and `b` truly concurrent?
    pub fn concurrent(&self, a: u64, b: u64) -> bool {
        !self.leq(a, b) && !self.leq(b, a)
    }

    /// Classify the removal of `dropped` given the ids that survive.
    pub fn classify_drop(&self, dropped: u64, survivors: &[u64]) -> DropVerdict {
        if survivors.iter().any(|&s| self.leq(dropped, s)) {
            DropVerdict::CorrectSupersession
        } else {
            DropVerdict::LostUpdate
        }
    }

    /// Count (false, true) concurrent pairs among a GET's sibling ids.
    pub fn classify_siblings(&self, ids: &[u64]) -> (u64, u64) {
        let (mut false_pairs, mut true_pairs) = (0, 0);
        for (i, &a) in ids.iter().enumerate() {
            for &b in ids.iter().skip(i + 1) {
                if self.concurrent(a, b) {
                    true_pairs += 1;
                } else {
                    false_pairs += 1;
                }
            }
        }
        (false_pairs, true_pairs)
    }

    /// Is this value id registered (written through a traced path)?
    pub fn knows(&self, val_id: u64) -> bool {
        self.hist.contains_key(&val_id)
    }

    /// Number of tracked values.
    pub fn tracked(&self) -> usize {
        self.hist.len()
    }
}

/// One immutable snapshot of a [`SharedOracle`]'s verdict counters —
/// what transport-equivalence tests compare across worlds.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OracleVerdict {
    /// Concurrent updates destroyed (must stay 0 for DVV).
    pub lost_updates: u64,
    /// Drops where a survivor causally covered the dropped version.
    pub correct_supersessions: u64,
    /// Drops involving untraced values (0 in a fully traced workload).
    pub unaudited_drops: u64,
    /// Number of tracked values.
    pub tracked: usize,
}

/// Thread-safe [`Oracle`] adapter for the threaded cluster.
///
/// The single-threaded simulator owns its oracle directly; the threaded
/// [`crate::server::LocalCluster`] instead shares one `SharedOracle`
/// across connection/client threads: writes register through
/// [`on_write`](SharedOracle::on_write) *before* touching any store, and
/// every store mutation reports its before/after sibling sets through
/// [`record_drops`](SharedOracle::record_drops), which classifies each
/// discarded version as a correct supersession or a lost update and
/// accumulates the verdicts in lock-free counters.
#[derive(Debug, Default)]
pub struct SharedOracle {
    inner: Mutex<Oracle>,
    lost: AtomicU64,
    correct: AtomicU64,
    unaudited: AtomicU64,
}

impl SharedOracle {
    /// New empty shared oracle.
    pub fn new() -> SharedOracle {
        SharedOracle::default()
    }

    /// Register a write (see [`Oracle::on_write`]). Must happen before
    /// the value reaches any store so a concurrent drop elsewhere can
    /// never observe an unregistered id.
    pub fn on_write(
        &self,
        client: Actor,
        key: Key,
        val_id: u64,
        observed: &[u64],
    ) -> VersionVector {
        self.inner.lock().unwrap().on_write(client, key, val_id, observed)
    }

    /// Classify every value present in `before` but gone from `after`
    /// (one store mutation's sibling-set delta) and tally the verdicts.
    ///
    /// Drops involving *untraced* values (ids never registered through
    /// [`on_write`](SharedOracle::on_write)) are tallied as unaudited
    /// rather than guessed at: the oracle has no ground truth for them,
    /// and counting them as lost updates would falsely fail the
    /// zero-lost-updates invariant on mixed traced/untraced workloads.
    pub fn record_drops(&self, before: &[Val], after: &[Val]) {
        if before.is_empty() {
            return;
        }
        let survivors: Vec<u64> = after.iter().map(|v| v.id).collect();
        let inner = self.inner.lock().unwrap();
        for v in before {
            if survivors.contains(&v.id) {
                continue;
            }
            if !inner.knows(v.id) {
                self.unaudited.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            match inner.classify_drop(v.id, &survivors) {
                DropVerdict::CorrectSupersession => {
                    self.correct.fetch_add(1, Ordering::Relaxed);
                }
                DropVerdict::LostUpdate if survivors.iter().all(|&s| inner.knows(s)) => {
                    self.lost.fetch_add(1, Ordering::Relaxed);
                }
                // a survivor is untraced: coverage cannot be judged
                DropVerdict::LostUpdate => {
                    self.unaudited.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Count (false, true) concurrent pairs among a GET's sibling ids.
    pub fn classify_siblings(&self, ids: &[u64]) -> (u64, u64) {
        self.inner.lock().unwrap().classify_siblings(ids)
    }

    /// Concurrent updates destroyed so far (must stay 0 for DVV).
    pub fn lost_updates(&self) -> u64 {
        self.lost.load(Ordering::Relaxed)
    }

    /// Drops where a survivor causally covered the dropped version.
    pub fn correct_supersessions(&self) -> u64 {
        self.correct.load(Ordering::Relaxed)
    }

    /// Drops involving untraced values, for which no verdict is possible
    /// (0 in a fully traced workload).
    pub fn unaudited_drops(&self) -> u64 {
        self.unaudited.load(Ordering::Relaxed)
    }

    /// Number of tracked values.
    pub fn tracked(&self) -> usize {
        self.inner.lock().unwrap().tracked()
    }

    /// Run a closure against the underlying [`Oracle`] (final audits).
    pub fn with_inner<R>(&self, f: impl FnOnce(&Oracle) -> R) -> R {
        f(&self.inner.lock().unwrap())
    }

    /// Snapshot every verdict counter at once.
    pub fn verdict(&self) -> OracleVerdict {
        OracleVerdict {
            lost_updates: self.lost_updates(),
            correct_supersessions: self.correct_supersessions(),
            unaudited_drops: self.unaudited_drops(),
            tracked: self.tracked(),
        }
    }
}

/// Per-element ground truth inside a [`SetAudit`] (one audited set key).
///
/// Sequence numbers are assigned at op *completion*. Typed RMWs on one
/// key are serialized end to end (the cluster's per-key stripe lock, or
/// the DES's run-to-completion ops), so completion order equals effect
/// order — and every partial effect of a *failed* op happened before
/// the client saw the error, hence before the next seq.
#[derive(Debug, Clone, Copy, Default)]
struct ElemRecord {
    /// Completion seq of the last acked SADD (0 = never).
    last_acked_add: u64,
    /// Completion seq of the last SREM *attempt*, acked or failed
    /// (0 = never) — a failed remove may still have landed removals on
    /// a minority of replicas.
    last_remove_attempt: u64,
    /// Completion seq of the last acked SREM (0 = never).
    last_acked_remove: u64,
    /// Any SADD of this element ever failed: its dot may be parked on a
    /// minority replica outside every later read quorum, and can
    /// legitimately resurface after heal — absence claims are off.
    failed_add: bool,
    /// Any SADD attempt (acked or failed) ever happened.
    ever_added: bool,
}

/// Verdict over a final set membership, audited against the add-wins
/// observed-remove contract (see [`SetAudit`]). All three violation
/// counters must be zero for a correct ORSWOT.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SetVerdict {
    /// Elements whose last acked SADD outran every SREM attempt, yet
    /// are missing: an acked add was lost (must stay 0).
    pub lost_adds: u64,
    /// Elements an acked SREM removed after every acked SADD — with no
    /// in-doubt SADD that could legally resurface — yet are present
    /// (must stay 0).
    pub resurrections: u64,
    /// Present elements no SADD ever attempted (must stay 0).
    pub phantoms: u64,
    /// Acked SADDs recorded.
    pub acked_adds: u64,
    /// Acked SREMs recorded.
    pub acked_removes: u64,
}

#[derive(Debug, Default)]
struct SetAuditInner {
    seq: u64,
    elems: HashMap<Vec<u8>, ElemRecord>,
    acked_adds: u64,
    acked_removes: u64,
}

/// Ground-truth audit of one observed-remove set key under a concurrent
/// add/remove workload ([`crate::api::drive_set_workload`]).
///
/// Acked ops become claims; failed ops become *taint*, because an
/// in-doubt RMW may have partially landed: a failed SADD's dot can
/// survive on a minority replica (so the element may legally
/// resurface), and a failed SREM's removals can propagate by
/// anti-entropy (so the element may legally vanish). The
/// [`verdict`](SetAudit::verdict) therefore only claims presence when
/// an acked add outran every remove attempt, and absence when an acked
/// remove outran every acked add with no in-doubt add on record —
/// exactly the window where add-wins semantics are unconditional.
#[derive(Debug, Default)]
pub struct SetAudit {
    inner: Mutex<SetAuditInner>,
}

impl SetAudit {
    /// New empty audit (one per audited set key).
    pub fn new() -> SetAudit {
        SetAudit::default()
    }

    fn record(&self, elem: &[u8], f: impl FnOnce(&mut ElemRecord, u64, &mut SetAuditInner)) {
        let mut inner = self.inner.lock().unwrap();
        inner.seq += 1;
        let seq = inner.seq;
        let mut rec = inner.elems.get(elem).copied().unwrap_or_default();
        f(&mut rec, seq, &mut inner);
        inner.elems.insert(elem.to_vec(), rec);
    }

    /// Record an acked SADD of `elem`.
    pub fn add_ok(&self, elem: &[u8]) {
        self.record(elem, |rec, seq, inner| {
            rec.last_acked_add = seq;
            rec.ever_added = true;
            inner.acked_adds += 1;
        });
    }

    /// Record a failed (in-doubt) SADD of `elem`.
    pub fn add_failed(&self, elem: &[u8]) {
        self.record(elem, |rec, _seq, _inner| {
            rec.failed_add = true;
            rec.ever_added = true;
        });
    }

    /// Record an acked SREM of `elem`.
    pub fn remove_ok(&self, elem: &[u8]) {
        self.record(elem, |rec, seq, inner| {
            rec.last_remove_attempt = seq;
            rec.last_acked_remove = seq;
            inner.acked_removes += 1;
        });
    }

    /// Record a failed (in-doubt) SREM of `elem`.
    pub fn remove_failed(&self, elem: &[u8]) {
        self.record(elem, |rec, seq, _inner| {
            rec.last_remove_attempt = seq;
        });
    }

    /// Audit a final membership (read after faults heal and anti-entropy
    /// quiesces) against every claim on record.
    pub fn verdict(&self, membership: &[Vec<u8>]) -> SetVerdict {
        let inner = self.inner.lock().unwrap();
        let mut v = SetVerdict {
            acked_adds: inner.acked_adds,
            acked_removes: inner.acked_removes,
            ..SetVerdict::default()
        };
        for (elem, rec) in &inner.elems {
            let present = membership.contains(elem);
            let must_present =
                rec.last_acked_add > 0 && rec.last_acked_add > rec.last_remove_attempt;
            let must_absent = !rec.failed_add
                && rec.last_acked_remove > 0
                && rec.last_acked_remove > rec.last_acked_add;
            if must_present && !present {
                v.lost_adds += 1;
            }
            if must_absent && present {
                v.resurrections += 1;
            }
        }
        for elem in membership {
            let attempted = inner.elems.get(elem).is_some_and(|rec| rec.ever_added);
            if !attempted {
                v.phantoms += 1;
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(i: u32) -> Actor {
        Actor::client(i)
    }

    #[test]
    fn blind_writes_are_concurrent() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(1), 1, 101, &[]);
        assert!(o.concurrent(100, 101));
        assert_eq!(o.classify_drop(100, &[101]), DropVerdict::LostUpdate);
    }

    #[test]
    fn informed_write_supersedes() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(1), 1, 101, &[100]);
        assert!(o.leq(100, 101));
        assert_eq!(o.classify_drop(100, &[101]), DropVerdict::CorrectSupersession);
    }

    #[test]
    fn same_client_writes_are_ordered() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(0), 1, 101, &[]); // blind, but same sequential client
        assert!(o.leq(100, 101), "a client's own writes are causally ordered");
    }

    #[test]
    fn per_key_counters_are_independent() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(0), 2, 200, &[]);
        let h1 = o.history_of(100);
        let h2 = o.history_of(200);
        // both are (C1,1) under their own key's counter — distinct keys
        // never interact so this is safe
        assert_eq!(h1.get(c(0)), 1);
        assert_eq!(h2.get(c(0)), 1);
    }

    #[test]
    fn reconciliation_write_covers_both() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(1), 1, 101, &[]);
        o.on_write(c(2), 1, 102, &[100, 101]); // read both siblings, merged
        assert!(o.leq(100, 102) && o.leq(101, 102));
        assert_eq!(o.classify_siblings(&[100, 101]), (0, 1));
        assert_eq!(o.classify_siblings(&[100, 102]), (1, 0));
    }

    #[test]
    fn unknown_values_never_leq() {
        let o = Oracle::new();
        assert!(!o.leq(1, 2));
        assert_eq!(o.classify_drop(1, &[2]), DropVerdict::LostUpdate);
    }

    #[test]
    fn shared_oracle_tallies_verdicts() {
        let o = SharedOracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(1), 1, 101, &[100]); // informed: supersedes 100
        o.on_write(c(2), 1, 102, &[]); // blind: concurrent with both
        // 100 dropped, 101 survives -> correct supersession
        o.record_drops(&[Val::new(100, 0), Val::new(101, 0)], &[Val::new(101, 0)]);
        // 102 dropped with only 101 surviving -> lost update
        o.record_drops(&[Val::new(101, 0), Val::new(102, 0)], &[Val::new(101, 0)]);
        assert_eq!(o.correct_supersessions(), 1);
        assert_eq!(o.lost_updates(), 1);
        assert_eq!(o.tracked(), 3);
        assert_eq!(o.classify_siblings(&[101, 102]), (0, 1));
        assert!(o.with_inner(|inner| inner.leq(100, 101)));
    }

    #[test]
    fn shared_oracle_leaves_untraced_values_unjudged() {
        let o = SharedOracle::new();
        o.on_write(c(0), 1, 100, &[]);
        // an unregistered id dropped: no claim either way
        o.record_drops(&[Val::new(999, 0)], &[Val::new(100, 0)]);
        // a registered value displaced by an unregistered survivor:
        // unauditable, NOT a lost update
        o.record_drops(&[Val::new(100, 0)], &[Val::new(999, 0)]);
        assert_eq!(o.lost_updates(), 0);
        assert_eq!(o.correct_supersessions(), 0);
        assert_eq!(o.unaudited_drops(), 2);
    }

    #[test]
    fn set_audit_demands_acked_adds_survive() {
        let a = SetAudit::new();
        a.add_ok(b"x");
        // absent despite an unchallenged acked add -> lost
        let v = a.verdict(&[]);
        assert_eq!(v.lost_adds, 1);
        assert_eq!((v.resurrections, v.phantoms), (0, 0));
        // present -> clean
        let v = a.verdict(&[b"x".to_vec()]);
        assert_eq!((v.lost_adds, v.resurrections, v.phantoms), (0, 0, 0));
        assert_eq!(v.acked_adds, 1);
    }

    #[test]
    fn set_audit_demands_acked_removes_stick() {
        let a = SetAudit::new();
        a.add_ok(b"x");
        a.remove_ok(b"x");
        let v = a.verdict(&[b"x".to_vec()]);
        assert_eq!(v.resurrections, 1, "removed element resurfaced");
        assert_eq!(a.verdict(&[]).resurrections, 0);
        // a later acked add re-establishes presence
        a.add_ok(b"x");
        let v = a.verdict(&[b"x".to_vec()]);
        assert_eq!((v.lost_adds, v.resurrections), (0, 0));
        assert_eq!(a.verdict(&[]).lost_adds, 1);
    }

    #[test]
    fn set_audit_failed_ops_taint_claims_both_ways() {
        let a = SetAudit::new();
        // a failed add may have parked a dot: absence AND presence both legal
        a.add_failed(b"x");
        a.remove_ok(b"x");
        assert_eq!(a.verdict(&[b"x".to_vec()]).resurrections, 0);
        assert_eq!(a.verdict(&[]).lost_adds, 0);
        // a failed remove may have landed removals: presence claim is off
        let b = SetAudit::new();
        b.add_ok(b"y");
        b.remove_failed(b"y");
        assert_eq!(b.verdict(&[]).lost_adds, 0);
        assert_eq!(b.verdict(&[b"y".to_vec()]).resurrections, 0);
        // but an acked add AFTER the in-doubt remove restores the claim
        b.add_ok(b"y");
        assert_eq!(b.verdict(&[]).lost_adds, 1);
    }

    #[test]
    fn set_audit_flags_phantoms() {
        let a = SetAudit::new();
        a.add_ok(b"x");
        let v = a.verdict(&[b"x".to_vec(), b"ghost".to_vec()]);
        assert_eq!(v.phantoms, 1);
        // failed adds are attempts: their elements are not phantoms
        a.add_failed(b"ghost");
        assert_eq!(a.verdict(&[b"x".to_vec(), b"ghost".to_vec()]).phantoms, 0);
    }

    #[test]
    fn shared_oracle_is_shareable_across_threads() {
        use std::sync::Arc;
        let o = Arc::new(SharedOracle::new());
        let mut handles = Vec::new();
        for t in 0..4u32 {
            let o = Arc::clone(&o);
            handles.push(std::thread::spawn(move || {
                // each thread is one sequential client: its writes chain
                let mut prev: Option<u64> = None;
                for i in 0..50u64 {
                    let id = u64::from(t) * 1000 + i;
                    let observed: Vec<u64> = prev.into_iter().collect();
                    o.on_write(Actor::client(t), 7, id, &observed);
                    if let Some(p) = prev {
                        o.record_drops(&[Val::new(p, 0)], &[Val::new(id, 0)]);
                    }
                    prev = Some(id);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(o.tracked(), 200);
        assert_eq!(o.lost_updates(), 0, "chained drops are all supersessions");
        assert_eq!(o.correct_supersessions(), 4 * 49);
    }
}
