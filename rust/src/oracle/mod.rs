//! Ground-truth causality oracle.
//!
//! Runs beside any mechanism under test and tracks the *true* causal
//! history of every written value. Because each client is sequential, true
//! causality is exactly representable as a version vector over client
//! actors (per key) — the §3.3 observation that per-client entries match
//! the sources of concurrency. The oracle uses this to classify every
//! version the mechanism discards as either a **correct supersession**
//! (the surviving value causally covers it) or a **lost update** (it does
//! not), and every sibling pair returned by a GET as **truly concurrent**
//! or **falsely concurrent**.

use std::collections::HashMap;

use crate::clocks::{Actor, VersionVector};
use crate::store::Key;

/// Verdict for one discarded value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropVerdict {
    /// Some surviving value causally dominates the dropped one.
    CorrectSupersession,
    /// No survivor covers it: a concurrent update was destroyed.
    LostUpdate,
}

/// The ground-truth tracker.
#[derive(Debug, Clone, Default)]
pub struct Oracle {
    /// True history of each value id, as a client-indexed version vector.
    hist: HashMap<u64, VersionVector>,
    /// Per (client, key) sequential write counters.
    counters: HashMap<(Actor, Key), u64>,
}

impl Oracle {
    /// New empty oracle.
    pub fn new() -> Oracle {
        Oracle::default()
    }

    /// Register a write: `client` wrote value `val_id` to `key`, having
    /// last observed the values in `observed` (ids from its latest GET of
    /// this key, empty for a blind write). Returns the true history
    /// assigned to the new value.
    pub fn on_write(
        &mut self,
        client: Actor,
        key: Key,
        val_id: u64,
        observed: &[u64],
    ) -> VersionVector {
        let mut vv = VersionVector::new();
        for id in observed {
            if let Some(h) = self.hist.get(id) {
                vv.join_from(h);
            }
        }
        let counter = self.counters.entry((client, key)).or_insert(0);
        *counter += 1;
        vv.set(client, *counter);
        self.hist.insert(val_id, vv.clone());
        vv
    }

    /// True history of a value (empty when unknown).
    pub fn history_of(&self, val_id: u64) -> VersionVector {
        self.hist.get(&val_id).cloned().unwrap_or_default()
    }

    /// Does value `a` causally precede-or-equal value `b`?
    pub fn leq(&self, a: u64, b: u64) -> bool {
        match (self.hist.get(&a), self.hist.get(&b)) {
            (Some(ha), Some(hb)) => ha.dominated_by(hb),
            _ => false,
        }
    }

    /// Are values `a` and `b` truly concurrent?
    pub fn concurrent(&self, a: u64, b: u64) -> bool {
        !self.leq(a, b) && !self.leq(b, a)
    }

    /// Classify the removal of `dropped` given the ids that survive.
    pub fn classify_drop(&self, dropped: u64, survivors: &[u64]) -> DropVerdict {
        if survivors.iter().any(|&s| self.leq(dropped, s)) {
            DropVerdict::CorrectSupersession
        } else {
            DropVerdict::LostUpdate
        }
    }

    /// Count (false, true) concurrent pairs among a GET's sibling ids.
    pub fn classify_siblings(&self, ids: &[u64]) -> (u64, u64) {
        let (mut false_pairs, mut true_pairs) = (0, 0);
        for (i, &a) in ids.iter().enumerate() {
            for &b in ids.iter().skip(i + 1) {
                if self.concurrent(a, b) {
                    true_pairs += 1;
                } else {
                    false_pairs += 1;
                }
            }
        }
        (false_pairs, true_pairs)
    }

    /// Number of tracked values.
    pub fn tracked(&self) -> usize {
        self.hist.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(i: u32) -> Actor {
        Actor::client(i)
    }

    #[test]
    fn blind_writes_are_concurrent() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(1), 1, 101, &[]);
        assert!(o.concurrent(100, 101));
        assert_eq!(o.classify_drop(100, &[101]), DropVerdict::LostUpdate);
    }

    #[test]
    fn informed_write_supersedes() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(1), 1, 101, &[100]);
        assert!(o.leq(100, 101));
        assert_eq!(o.classify_drop(100, &[101]), DropVerdict::CorrectSupersession);
    }

    #[test]
    fn same_client_writes_are_ordered() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(0), 1, 101, &[]); // blind, but same sequential client
        assert!(o.leq(100, 101), "a client's own writes are causally ordered");
    }

    #[test]
    fn per_key_counters_are_independent() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(0), 2, 200, &[]);
        let h1 = o.history_of(100);
        let h2 = o.history_of(200);
        // both are (C1,1) under their own key's counter — distinct keys
        // never interact so this is safe
        assert_eq!(h1.get(c(0)), 1);
        assert_eq!(h2.get(c(0)), 1);
    }

    #[test]
    fn reconciliation_write_covers_both() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(1), 1, 101, &[]);
        o.on_write(c(2), 1, 102, &[100, 101]); // read both siblings, merged
        assert!(o.leq(100, 102) && o.leq(101, 102));
        assert_eq!(o.classify_siblings(&[100, 101]), (0, 1));
        assert_eq!(o.classify_siblings(&[100, 102]), (1, 0));
    }

    #[test]
    fn unknown_values_never_leq() {
        let o = Oracle::new();
        assert!(!o.leq(1, 2));
        assert_eq!(o.classify_drop(1, &[2]), DropVerdict::LostUpdate);
    }
}
