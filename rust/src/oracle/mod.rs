//! Ground-truth causality oracle.
//!
//! Runs beside any mechanism under test and tracks the *true* causal
//! history of every written value. Because each client is sequential, true
//! causality is exactly representable as a version vector over client
//! actors (per key) — the §3.3 observation that per-client entries match
//! the sources of concurrency. The oracle uses this to classify every
//! version the mechanism discards as either a **correct supersession**
//! (the surviving value causally covers it) or a **lost update** (it does
//! not), and every sibling pair returned by a GET as **truly concurrent**
//! or **falsely concurrent**.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::clocks::{Actor, VersionVector};
use crate::kernel::Val;
use crate::store::Key;

/// Verdict for one discarded value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropVerdict {
    /// Some surviving value causally dominates the dropped one.
    CorrectSupersession,
    /// No survivor covers it: a concurrent update was destroyed.
    LostUpdate,
}

/// The ground-truth tracker.
#[derive(Debug, Clone, Default)]
pub struct Oracle {
    /// True history of each value id, as a client-indexed version vector.
    hist: HashMap<u64, VersionVector>,
    /// Per (client, key) sequential write counters.
    counters: HashMap<(Actor, Key), u64>,
}

impl Oracle {
    /// New empty oracle.
    pub fn new() -> Oracle {
        Oracle::default()
    }

    /// Register a write: `client` wrote value `val_id` to `key`, having
    /// last observed the values in `observed` (ids from its latest GET of
    /// this key, empty for a blind write). Returns the true history
    /// assigned to the new value.
    pub fn on_write(
        &mut self,
        client: Actor,
        key: Key,
        val_id: u64,
        observed: &[u64],
    ) -> VersionVector {
        let mut vv = VersionVector::new();
        for id in observed {
            if let Some(h) = self.hist.get(id) {
                vv.join_from(h);
            }
        }
        let counter = self.counters.entry((client, key)).or_insert(0);
        *counter += 1;
        vv.set(client, *counter);
        self.hist.insert(val_id, vv.clone());
        vv
    }

    /// True history of a value (empty when unknown).
    pub fn history_of(&self, val_id: u64) -> VersionVector {
        self.hist.get(&val_id).cloned().unwrap_or_default()
    }

    /// Does value `a` causally precede-or-equal value `b`?
    pub fn leq(&self, a: u64, b: u64) -> bool {
        match (self.hist.get(&a), self.hist.get(&b)) {
            (Some(ha), Some(hb)) => ha.dominated_by(hb),
            _ => false,
        }
    }

    /// Are values `a` and `b` truly concurrent?
    pub fn concurrent(&self, a: u64, b: u64) -> bool {
        !self.leq(a, b) && !self.leq(b, a)
    }

    /// Classify the removal of `dropped` given the ids that survive.
    pub fn classify_drop(&self, dropped: u64, survivors: &[u64]) -> DropVerdict {
        if survivors.iter().any(|&s| self.leq(dropped, s)) {
            DropVerdict::CorrectSupersession
        } else {
            DropVerdict::LostUpdate
        }
    }

    /// Count (false, true) concurrent pairs among a GET's sibling ids.
    pub fn classify_siblings(&self, ids: &[u64]) -> (u64, u64) {
        let (mut false_pairs, mut true_pairs) = (0, 0);
        for (i, &a) in ids.iter().enumerate() {
            for &b in ids.iter().skip(i + 1) {
                if self.concurrent(a, b) {
                    true_pairs += 1;
                } else {
                    false_pairs += 1;
                }
            }
        }
        (false_pairs, true_pairs)
    }

    /// Is this value id registered (written through a traced path)?
    pub fn knows(&self, val_id: u64) -> bool {
        self.hist.contains_key(&val_id)
    }

    /// Number of tracked values.
    pub fn tracked(&self) -> usize {
        self.hist.len()
    }
}

/// One immutable snapshot of a [`SharedOracle`]'s verdict counters —
/// what transport-equivalence tests compare across worlds.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OracleVerdict {
    /// Concurrent updates destroyed (must stay 0 for DVV).
    pub lost_updates: u64,
    /// Drops where a survivor causally covered the dropped version.
    pub correct_supersessions: u64,
    /// Drops involving untraced values (0 in a fully traced workload).
    pub unaudited_drops: u64,
    /// Number of tracked values.
    pub tracked: usize,
}

/// Thread-safe [`Oracle`] adapter for the threaded cluster.
///
/// The single-threaded simulator owns its oracle directly; the threaded
/// [`crate::server::LocalCluster`] instead shares one `SharedOracle`
/// across connection/client threads: writes register through
/// [`on_write`](SharedOracle::on_write) *before* touching any store, and
/// every store mutation reports its before/after sibling sets through
/// [`record_drops`](SharedOracle::record_drops), which classifies each
/// discarded version as a correct supersession or a lost update and
/// accumulates the verdicts in lock-free counters.
#[derive(Debug, Default)]
pub struct SharedOracle {
    inner: Mutex<Oracle>,
    lost: AtomicU64,
    correct: AtomicU64,
    unaudited: AtomicU64,
}

impl SharedOracle {
    /// New empty shared oracle.
    pub fn new() -> SharedOracle {
        SharedOracle::default()
    }

    /// Register a write (see [`Oracle::on_write`]). Must happen before
    /// the value reaches any store so a concurrent drop elsewhere can
    /// never observe an unregistered id.
    pub fn on_write(
        &self,
        client: Actor,
        key: Key,
        val_id: u64,
        observed: &[u64],
    ) -> VersionVector {
        self.inner.lock().unwrap().on_write(client, key, val_id, observed)
    }

    /// Classify every value present in `before` but gone from `after`
    /// (one store mutation's sibling-set delta) and tally the verdicts.
    ///
    /// Drops involving *untraced* values (ids never registered through
    /// [`on_write`](SharedOracle::on_write)) are tallied as unaudited
    /// rather than guessed at: the oracle has no ground truth for them,
    /// and counting them as lost updates would falsely fail the
    /// zero-lost-updates invariant on mixed traced/untraced workloads.
    pub fn record_drops(&self, before: &[Val], after: &[Val]) {
        if before.is_empty() {
            return;
        }
        let survivors: Vec<u64> = after.iter().map(|v| v.id).collect();
        let inner = self.inner.lock().unwrap();
        for v in before {
            if survivors.contains(&v.id) {
                continue;
            }
            if !inner.knows(v.id) {
                self.unaudited.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            match inner.classify_drop(v.id, &survivors) {
                DropVerdict::CorrectSupersession => {
                    self.correct.fetch_add(1, Ordering::Relaxed);
                }
                DropVerdict::LostUpdate if survivors.iter().all(|&s| inner.knows(s)) => {
                    self.lost.fetch_add(1, Ordering::Relaxed);
                }
                // a survivor is untraced: coverage cannot be judged
                DropVerdict::LostUpdate => {
                    self.unaudited.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Count (false, true) concurrent pairs among a GET's sibling ids.
    pub fn classify_siblings(&self, ids: &[u64]) -> (u64, u64) {
        self.inner.lock().unwrap().classify_siblings(ids)
    }

    /// Concurrent updates destroyed so far (must stay 0 for DVV).
    pub fn lost_updates(&self) -> u64 {
        self.lost.load(Ordering::Relaxed)
    }

    /// Drops where a survivor causally covered the dropped version.
    pub fn correct_supersessions(&self) -> u64 {
        self.correct.load(Ordering::Relaxed)
    }

    /// Drops involving untraced values, for which no verdict is possible
    /// (0 in a fully traced workload).
    pub fn unaudited_drops(&self) -> u64 {
        self.unaudited.load(Ordering::Relaxed)
    }

    /// Number of tracked values.
    pub fn tracked(&self) -> usize {
        self.inner.lock().unwrap().tracked()
    }

    /// Run a closure against the underlying [`Oracle`] (final audits).
    pub fn with_inner<R>(&self, f: impl FnOnce(&Oracle) -> R) -> R {
        f(&self.inner.lock().unwrap())
    }

    /// Snapshot every verdict counter at once.
    pub fn verdict(&self) -> OracleVerdict {
        OracleVerdict {
            lost_updates: self.lost_updates(),
            correct_supersessions: self.correct_supersessions(),
            unaudited_drops: self.unaudited_drops(),
            tracked: self.tracked(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(i: u32) -> Actor {
        Actor::client(i)
    }

    #[test]
    fn blind_writes_are_concurrent() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(1), 1, 101, &[]);
        assert!(o.concurrent(100, 101));
        assert_eq!(o.classify_drop(100, &[101]), DropVerdict::LostUpdate);
    }

    #[test]
    fn informed_write_supersedes() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(1), 1, 101, &[100]);
        assert!(o.leq(100, 101));
        assert_eq!(o.classify_drop(100, &[101]), DropVerdict::CorrectSupersession);
    }

    #[test]
    fn same_client_writes_are_ordered() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(0), 1, 101, &[]); // blind, but same sequential client
        assert!(o.leq(100, 101), "a client's own writes are causally ordered");
    }

    #[test]
    fn per_key_counters_are_independent() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(0), 2, 200, &[]);
        let h1 = o.history_of(100);
        let h2 = o.history_of(200);
        // both are (C1,1) under their own key's counter — distinct keys
        // never interact so this is safe
        assert_eq!(h1.get(c(0)), 1);
        assert_eq!(h2.get(c(0)), 1);
    }

    #[test]
    fn reconciliation_write_covers_both() {
        let mut o = Oracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(1), 1, 101, &[]);
        o.on_write(c(2), 1, 102, &[100, 101]); // read both siblings, merged
        assert!(o.leq(100, 102) && o.leq(101, 102));
        assert_eq!(o.classify_siblings(&[100, 101]), (0, 1));
        assert_eq!(o.classify_siblings(&[100, 102]), (1, 0));
    }

    #[test]
    fn unknown_values_never_leq() {
        let o = Oracle::new();
        assert!(!o.leq(1, 2));
        assert_eq!(o.classify_drop(1, &[2]), DropVerdict::LostUpdate);
    }

    #[test]
    fn shared_oracle_tallies_verdicts() {
        let o = SharedOracle::new();
        o.on_write(c(0), 1, 100, &[]);
        o.on_write(c(1), 1, 101, &[100]); // informed: supersedes 100
        o.on_write(c(2), 1, 102, &[]); // blind: concurrent with both
        // 100 dropped, 101 survives -> correct supersession
        o.record_drops(&[Val::new(100, 0), Val::new(101, 0)], &[Val::new(101, 0)]);
        // 102 dropped with only 101 surviving -> lost update
        o.record_drops(&[Val::new(101, 0), Val::new(102, 0)], &[Val::new(101, 0)]);
        assert_eq!(o.correct_supersessions(), 1);
        assert_eq!(o.lost_updates(), 1);
        assert_eq!(o.tracked(), 3);
        assert_eq!(o.classify_siblings(&[101, 102]), (0, 1));
        assert!(o.with_inner(|inner| inner.leq(100, 101)));
    }

    #[test]
    fn shared_oracle_leaves_untraced_values_unjudged() {
        let o = SharedOracle::new();
        o.on_write(c(0), 1, 100, &[]);
        // an unregistered id dropped: no claim either way
        o.record_drops(&[Val::new(999, 0)], &[Val::new(100, 0)]);
        // a registered value displaced by an unregistered survivor:
        // unauditable, NOT a lost update
        o.record_drops(&[Val::new(100, 0)], &[Val::new(999, 0)]);
        assert_eq!(o.lost_updates(), 0);
        assert_eq!(o.correct_supersessions(), 0);
        assert_eq!(o.unaudited_drops(), 2);
    }

    #[test]
    fn shared_oracle_is_shareable_across_threads() {
        use std::sync::Arc;
        let o = Arc::new(SharedOracle::new());
        let mut handles = Vec::new();
        for t in 0..4u32 {
            let o = Arc::clone(&o);
            handles.push(std::thread::spawn(move || {
                // each thread is one sequential client: its writes chain
                let mut prev: Option<u64> = None;
                for i in 0..50u64 {
                    let id = u64::from(t) * 1000 + i;
                    let observed: Vec<u64> = prev.into_iter().collect();
                    o.on_write(Actor::client(t), 7, id, &observed);
                    if let Some(p) = prev {
                        o.record_drops(&[Val::new(p, 0)], &[Val::new(id, 0)]);
                    }
                    prev = Some(id);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(o.tracked(), 200);
        assert_eq!(o.lost_updates(), 0, "chained drops are all supersessions");
        assert_eq!(o.correct_supersessions(), 4 * 49);
    }
}
