//! Deterministic discrete-event cluster simulator.
//!
//! Single-threaded virtual-time DES: every message hop, client op, and
//! anti-entropy round is an event in a priority queue. Given `(seed,
//! config, driver)` a run is reproducible bit-for-bit — which is what lets
//! the figure replays assert the paper's exact states and E6/E9 compare
//! mechanisms on *identical* interleavings.
//!
//! The §4.1 message flows are implemented faithfully:
//!
//! * GET (Fig. 5): client → coordinator; coordinator fans `GetSub` to the
//!   key's preference list, reduces replies via the mechanism's `merge`
//!   (= kernel `sync`), answers the client at `R` replies, and
//!   read-repairs all replicas once every reply arrived.
//! * PUT (Fig. 6): client → coordinator (first live node of the
//!   preference list); coordinator runs the mechanism's `update`+`sync`,
//!   fans the resulting state to the other replicas, answers at `W` acks.
//! * Anti-entropy: periodic pairwise full-state exchange.
//! * Geo mode (`cluster.zones` set): placement spreads each preference
//!   list across DCs, writes commit on a per-DC sloppy quorum (R/W count
//!   only coordinator-zone replicas), and a per-node cross-DC shipper
//!   streams HLC-stamped state batches to remote-DC homes on
//!   `Ev::ShipTick` — with mostly-intra-DC anti-entropy plus a
//!   low-frequency cross-DC round as the repair backstop.

pub mod failure;

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap, HashSet};

use crate::clocks::{Actor, Hlc, HlcTimestamp};
use crate::cluster::{NodeId, Ring};
use crate::config::StoreConfig;
use crate::coordinator::{GetOp, PutOp, QuorumSpec};
use crate::kernel::crdt::{mint_actor, CrdtKind, Dot, TypedState};
use crate::kernel::{Mechanism, Val, WriteMeta};
use crate::metrics::Metrics;
use crate::net::NetModel;
use crate::oracle::{DropVerdict, Oracle};
use crate::session::{ClientSession, PutResult};
use crate::store::{Key, KeyStore, StorageBackend};
use crate::testkit::Rng;
use crate::workload::{Driver, Op, OpKind};

/// Timeout for in-flight client ops (simulated µs).
const OP_TIMEOUT_US: u64 = 100_000;

/// One simulated replica node.
#[derive(Debug, Clone)]
pub struct SimNode<M: Mechanism> {
    /// The node's versioned store.
    pub store: KeyStore<M>,
    /// Crashed nodes drop every message addressed to them.
    pub up: bool,
    /// Active members own ring ranges and source anti-entropy; a
    /// decommissioned node (`member = false`) keeps draining what it
    /// still holds toward the members, but routes no new traffic.
    pub member: bool,
    /// The DES durability model's "disk": the last **persisted** state
    /// per key — what the real backend's WAL replay would rebuild
    /// (replay is last-record-wins, so keeping only the latest synced
    /// state per key is exact, in O(keys) instead of O(mutations)).
    /// Empty (and never written) when `durability.flush_every_ops` is 0.
    pub synced: HashMap<Key, M::State>,
    /// Mutations since the last flush, in order — the unsynced WAL tail
    /// a [`Sim::schedule_restart`] loses. Folded into `synced` every
    /// `flush_every_ops` mutations, mirroring `FsyncPolicy::EveryN`.
    pub unsynced: Vec<(Key, M::State)>,
    /// Hybrid logical clock (geo mode): advanced on coordinator writes
    /// and ship-batch receipts; strictly monotone per node even under
    /// [`Sim::schedule_clock_skew`] jumps.
    pub hlc: Hlc,
    /// Keys with updates parked for cross-DC shipment (deduplicated).
    /// The shipper snapshots the *current* state at drain time, so a key
    /// superseded while parked ships once, with the newest state.
    pub ship: Vec<Key>,
    /// Injected physical-clock offset (µs, cumulative, signed): the
    /// node's physical time reads `now + skew_us`, floored at 0.
    pub skew_us: i64,
    /// Mint-actor generation for typed CRDT ops: bumped on restart and
    /// wipe, because losing local state voids the promise that this
    /// node's store holds every dot it ever minted (the false-cover
    /// hazard — [`crate::kernel::crdt`] module docs). Mirrors the
    /// threaded `Node::typed_epoch`.
    pub typed_epoch: u64,
}

impl<M: Mechanism> SimNode<M> {
    fn fresh(mech: &M) -> SimNode<M> {
        SimNode {
            store: KeyStore::new(mech.clone()),
            up: true,
            member: true,
            synced: HashMap::new(),
            unsynced: Vec::new(),
            hlc: Hlc::new(),
            ship: Vec::new(),
            skew_us: 0,
            typed_epoch: 0,
        }
    }
}

/// Messages exchanged between nodes.
#[derive(Debug, Clone)]
enum Msg<M: Mechanism> {
    /// Client-originated GET arriving at the coordinator.
    GetClient { req: u64, key: Key },
    /// Coordinator → replica read.
    GetSub { req: u64, key: Key, from: NodeId },
    /// Replica → coordinator state reply.
    GetSubResp { req: u64, state: M::State },
    /// Client-originated PUT arriving at the coordinator.
    PutClient { req: u64, key: Key, ctx: M::Context, val: Val, meta: WriteMeta },
    /// Coordinator → replica replication of the synced state (§4.1 step 4).
    Replicate { req: u64, key: Key, state: M::State, from: NodeId },
    /// Replica → coordinator replication ack.
    ReplicateAck { req: u64 },
    /// Read repair / anti-entropy state push (no ack).
    StatePush { key: Key, state: M::State },
    /// Anti-entropy request: peer replies with its states for these keys.
    AePull { keys: Vec<Key>, from: NodeId },
    /// Anti-entropy reply.
    AePush { states: Vec<(Key, M::State)> },
    /// Cross-DC shipper batch: HLC-stamped current states for keys homed
    /// (in part) at the receiving remote-DC node.
    ShipBatch { states: Vec<(Key, M::State)>, ts: HlcTimestamp },
}

/// Scheduled event kinds.
enum Ev<M: Mechanism> {
    Deliver { to: NodeId, msg: Msg<M> },
    ClientIssue { client: usize, op: Op },
    ClientDone { client: usize, req: u64 },
    OpTimeout { req: u64 },
    AeTick { node: NodeId },
    ShipTick { node: NodeId },
    ClockSkew { node: NodeId, delta_us: i64 },
    Crash { node: NodeId },
    Recover { node: NodeId },
    PartitionGroups { left: Vec<NodeId>, right: Vec<NodeId> },
    HealAll,
    Degrade { drop_ppm: u32, extra_delay_us: u64 },
    Join,
    Decommission { node: NodeId },
    Restart { node: NodeId },
    Wipe { node: NodeId },
}

struct Queued<M: Mechanism> {
    at: u64,
    seq: u64,
    ev: Ev<M>,
}

impl<M: Mechanism> PartialEq for Queued<M> {
    fn eq(&self, other: &Self) -> bool {
        (self.at, self.seq) == (other.at, other.seq)
    }
}
impl<M: Mechanism> Eq for Queued<M> {}
impl<M: Mechanism> PartialOrd for Queued<M> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<M: Mechanism> Ord for Queued<M> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Outcome of a synchronous (API-driven) client op; see
/// [`Sim::sync_get`] / [`Sim::sync_put`].
#[derive(Debug, Clone)]
enum SyncDone<M: Mechanism> {
    /// A GET answered: sibling values plus the causal context.
    Get { values: Vec<Val>, ctx: M::Context },
    /// A PUT completed: the new write's id plus the coordinator's
    /// post-write context — `Some` only when the write left no
    /// concurrent siblings (see [`Sim::sync_put`]).
    Put { id: u64, ctx: Option<M::Context> },
}

/// In-flight client op bookkeeping at its coordinator.
enum Pending<M: Mechanism> {
    Get {
        client: usize,
        key: Key,
        op: GetOp<M>,
        started: u64,
        participants: Vec<NodeId>,
    },
    Put { client: usize, key: Key, op: PutOp, started: u64, val: Val },
}

/// The simulator.
pub struct Sim<M: Mechanism> {
    mech: M,
    cfg: StoreConfig,
    /// Cluster ring (public for topology-aware tests).
    pub ring: Ring,
    /// Replica nodes.
    pub nodes: Vec<SimNode<M>>,
    net: NetModel,
    queue: BinaryHeap<Reverse<Queued<M>>>,
    now: u64,
    seq: u64,
    /// Run metrics.
    pub metrics: Metrics,
    /// Ground-truth tracker.
    pub oracle: Oracle,
    /// Client sessions.
    pub sessions: Vec<ClientSession<M>>,
    pending: HashMap<u64, Pending<M>>,
    /// Requests issued through the synchronous API ([`Sim::sync_get`] /
    /// [`Sim::sync_put`]) still awaiting resolution.
    sync_waiting: HashSet<u64>,
    /// Resolved synchronous requests, consumed by [`Sim::run_sync`].
    sync_done: HashMap<u64, crate::Result<SyncDone<M>>>,
    driver: Box<dyn Driver>,
    rng: Rng,
    next_req: u64,
    next_val: u64,
    /// (key, val_id) of every write issued (final audit).
    written: Vec<(Key, u64)>,
    /// (key, val_id) of every write **acknowledged** to its client (the
    /// stronger durability audit: an acked write may never be lost, even
    /// across restarts with state loss — an unacked one legitimately may
    /// vanish when every replica that held it loses state).
    acked: Vec<(Key, u64)>,
    quorum: QuorumSpec,
    /// Typed-op payload side table: encoded [`TypedState`] per write id
    /// — the DES analogue of the threaded cluster's blob store. The
    /// register fabric moves value *identities*; typed payload bytes
    /// live here, keyed by the id the register write was assigned.
    typed_blobs: HashMap<u64, Vec<u8>>,
    /// Clients whose drivers returned `None` (retired).
    retired: usize,
    /// Membership epoch: bumped once per join/decommission, mirroring
    /// [`crate::cluster::Topology`] in the threaded world.
    epoch: u64,
}

impl<M: Mechanism> Sim<M> {
    /// Build a simulator: `mech` + config + client count + op driver.
    pub fn new(
        mech: M,
        cfg: StoreConfig,
        clients: usize,
        stateful_clients: bool,
        driver: Box<dyn Driver>,
        seed: u64,
    ) -> crate::Result<Sim<M>> {
        cfg.validate()?;
        let mut rng = Rng::new(seed);
        let ring = Ring::new(cfg.cluster.nodes, cfg.cluster.vnodes)?;
        let mut net = NetModel::new(cfg.net.clone(), rng.fork());
        let nodes = (0..cfg.cluster.nodes).map(|_| SimNode::fresh(&mech)).collect();
        let sessions = (0..clients)
            .map(|i| {
                let skew = net.draw_clock_skew(i);
                ClientSession::new(Actor::client(i as u32), stateful_clients, skew)
            })
            .collect();
        let quorum = QuorumSpec::new(
            cfg.cluster.replication,
            cfg.cluster.read_quorum,
            cfg.cluster.write_quorum,
        )?;
        Ok(Sim {
            mech,
            ring,
            nodes,
            net,
            queue: BinaryHeap::new(),
            now: 0,
            seq: 0,
            metrics: Metrics::new(),
            oracle: Oracle::new(),
            sessions,
            pending: HashMap::new(),
            sync_waiting: HashSet::new(),
            sync_done: HashMap::new(),
            driver,
            rng,
            next_req: 0,
            next_val: 1,
            written: Vec::new(),
            acked: Vec::new(),
            quorum,
            typed_blobs: HashMap::new(),
            retired: 0,
            epoch: crate::cluster::topology::INITIAL_EPOCH,
            cfg,
        })
    }

    /// Current simulated time (µs).
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Current membership epoch (starts at
    /// [`crate::cluster::topology::INITIAL_EPOCH`], bumps once per
    /// join/decommission — the same lifecycle as the threaded
    /// [`crate::cluster::Topology`]).
    pub fn topology_epoch(&self) -> u64 {
        self.epoch
    }

    /// Active member ids, ascending.
    pub fn members(&self) -> Vec<NodeId> {
        (0..self.nodes.len()).filter(|&n| self.nodes[n].member).collect()
    }

    /// Is geo-replication active (`cluster.zones` set)?
    pub fn geo(&self) -> bool {
        !self.cfg.cluster.zones.is_empty()
    }

    /// The DC `node` lives in (zone 0 when flat, and for nodes that
    /// joined after construction).
    pub fn zone_of(&self, node: NodeId) -> usize {
        self.cfg.cluster.zones.get(node).copied().unwrap_or(0)
    }

    /// The DC a client routes through: clients spread round-robin over
    /// the zone id space, so every DC has local users.
    pub fn client_zone(&self, client: usize) -> usize {
        let nz = self.cfg.cluster.zones.iter().copied().max().map_or(0, |m| m + 1);
        if nz == 0 {
            0
        } else {
            client % nz
        }
    }

    /// Last HLC timestamp `node` issued (drift audits, monotonicity
    /// tests).
    pub fn node_hlc(&self, node: NodeId) -> HlcTimestamp {
        self.nodes[node].hlc.last()
    }

    /// Keys still parked in cross-DC ship buffers, cluster-wide (the DES
    /// twin of the threaded cluster's `ship_lag` STATS field).
    pub fn ship_lag(&self) -> usize {
        self.nodes.iter().map(|n| n.ship.len()).sum()
    }

    /// `node`'s physical clock reading: simulated time plus its injected
    /// cumulative skew, floored at zero.
    fn phys(&self, node: NodeId) -> u64 {
        (self.now as i64 + self.nodes[node].skew_us).max(0) as u64
    }

    /// The key's preference list under the active placement policy.
    fn replicas(&self, key: Key) -> Vec<NodeId> {
        if self.geo() {
            self.ring.replicas_for_zoned(key, self.quorum.n, &self.cfg.cluster.zones)
        } else {
            self.ring.replicas_for(key, self.quorum.n)
        }
    }

    /// Per-DC sloppy quorum: in geo mode R/W count only replicas in the
    /// coordinator's zone (floored at 1) — remote-DC homes are fed
    /// asynchronously by the shipper and never gate the client reply.
    /// Flat clusters keep the global spec.
    fn scoped_quorum(&self, replicas: &[NodeId], coordinator: NodeId) -> QuorumSpec {
        if !self.geo() {
            return self.quorum;
        }
        let z = self.zone_of(coordinator);
        let local = replicas.iter().filter(|&&n| self.zone_of(n) == z).count().max(1);
        QuorumSpec::new(self.quorum.n, self.quorum.r.min(local), self.quorum.w.min(local))
            .expect("zone-scoped quorum stays valid")
    }

    fn push(&mut self, at: u64, ev: Ev<M>) {
        self.seq += 1;
        self.queue.push(Reverse(Queued { at, seq: self.seq, ev }));
    }

    fn send(&mut self, from: NodeId, to: NodeId, msg: Msg<M>) {
        self.metrics.messages += 1;
        match self.net.delay(from, to) {
            Some(d) => {
                let at = self.now + d;
                self.push(at, Ev::Deliver { to, msg });
            }
            None => self.metrics.dropped_messages += 1,
        }
    }

    /// Kick off every client's first op and any periodic anti-entropy.
    pub fn start(&mut self) {
        for client in 0..self.sessions.len() {
            self.schedule_next_op(client, 0);
        }
        if self.cfg.antientropy.period_us > 0 {
            for node in 0..self.nodes.len() {
                let jitter = self.rng.below(self.cfg.antientropy.period_us.max(1));
                self.push(self.now + jitter, Ev::AeTick { node });
            }
        }
        if self.geo() && self.cfg.geo.ship_interval_us > 0 {
            for node in 0..self.nodes.len() {
                let jitter = self.rng.below(self.cfg.geo.ship_interval_us.max(1));
                self.push(self.now + jitter, Ev::ShipTick { node });
            }
        }
    }

    /// Inject a crash at simulated time `at`.
    pub fn schedule_crash(&mut self, at: u64, node: NodeId) {
        self.push(at, Ev::Crash { node });
    }

    /// Inject a recovery at simulated time `at`.
    pub fn schedule_recover(&mut self, at: u64, node: NodeId) {
        self.push(at, Ev::Recover { node });
    }

    /// Partition the cluster into two groups at `at`.
    pub fn schedule_partition(&mut self, at: u64, left: Vec<NodeId>, right: Vec<NodeId>) {
        self.push(at, Ev::PartitionGroups { left, right });
    }

    /// Heal all partitions at `at`.
    pub fn schedule_heal(&mut self, at: u64) {
        self.push(at, Ev::HealAll);
    }

    /// Degrade the network at `at`: extra message loss (parts-per-million)
    /// plus a fixed extra per-message delay. `(0, 0)` restores the
    /// configured baseline.
    pub fn schedule_degrade(&mut self, at: u64, drop_ppm: u32, extra_delay_us: u64) {
        self.push(at, Ev::Degrade { drop_ppm, extra_delay_us });
    }

    /// Admit a new node at `at` (it takes the next dense id).
    pub fn schedule_join(&mut self, at: u64) {
        self.push(at, Ev::Join);
    }

    /// Retire `node` at `at`: its ranges re-route and its keys hand off.
    pub fn schedule_decommission(&mut self, at: u64, node: NodeId) {
        self.push(at, Ev::Decommission { node });
    }

    /// Crash-restart `node`'s process at `at`: the store rolls back to
    /// the persisted WAL prefix (`durability.flush_every_ops`; with the
    /// model off, to nothing). The node's `up` flag is untouched — model
    /// downtime with a surrounding crash window.
    pub fn schedule_restart(&mut self, at: u64, node: NodeId) {
        self.push(at, Ev::Restart { node });
    }

    /// Destroy `node`'s state — logical disk included — at `at`.
    pub fn schedule_wipe(&mut self, at: u64, node: NodeId) {
        self.push(at, Ev::Wipe { node });
    }

    /// Step `node`'s physical clock by `delta_us` (cumulative: two skews
    /// add) at `at` — the GentleRain+ anomaly driver. A negative delta
    /// makes the node's physical time run behind simulated time, which
    /// plain physical timestamps cannot survive but HLCs must.
    pub fn schedule_clock_skew(&mut self, at: u64, node: NodeId, delta_us: i64) {
        self.push(at, Ev::ClockSkew { node, delta_us });
    }

    fn schedule_next_op(&mut self, client: usize, extra_delay: u64) {
        if let Some(op) = self.driver.next_op(client, self.now, &mut self.rng) {
            let at = self.now + extra_delay + op.think_us;
            self.push(at, Ev::ClientIssue { client, op });
        } else {
            self.retired += 1;
        }
    }

    /// All clients retired and no ops in flight — the run is effectively
    /// over (periodic anti-entropy stops rescheduling so the queue can
    /// drain).
    fn workload_done(&self) -> bool {
        self.retired >= self.sessions.len() && self.pending.is_empty()
    }

    /// Run until the event queue drains (all clients retired) or `max_us`
    /// of virtual time passes.
    pub fn run(&mut self, max_us: u64) {
        while let Some(Reverse(q)) = self.queue.pop() {
            if q.at > max_us {
                break;
            }
            self.now = q.at;
            self.dispatch(q.ev);
        }
        self.finalize_metrics();
    }

    // ---------------------------------------------------------------
    // synchronous client API (the `crate::api::SimClient` transport)
    // ---------------------------------------------------------------

    /// Issue one GET for `client` *interactively*: the event queue runs
    /// (advancing virtual time, interleaving any scheduled faults or
    /// pending deliveries) until this op answers or times out. Session
    /// and oracle bookkeeping beyond the shared message flow is the
    /// caller's concern — this is the [`crate::api::SimClient`] entry
    /// point; the closed-loop driver world ([`Sim::start`]/[`Sim::run`])
    /// is unaffected.
    pub fn sync_get(&mut self, client: usize, key: Key) -> crate::Result<(Vec<Val>, M::Context)> {
        let zone = self.pref_zone(client);
        let Some((coordinator, replicas)) = self.pick_coordinator(key, zone) else {
            return Err(crate::Error::Unavailable("no live replica to coordinate".into()));
        };
        self.sync_get_at(client, key, coordinator, replicas)
    }

    /// Pinned variant of [`Sim::sync_get`]: the caller has already
    /// picked the coordinator (a typed RMW must read and write through
    /// the same node — the mint contract).
    fn sync_get_at(
        &mut self,
        client: usize,
        key: Key,
        coordinator: NodeId,
        replicas: Vec<NodeId>,
    ) -> crate::Result<(Vec<Val>, M::Context)> {
        let quorum = self.scoped_quorum(&replicas, coordinator);
        let req = self.next_req;
        self.next_req += 1;
        self.push(self.now + OP_TIMEOUT_US, Ev::OpTimeout { req });
        self.pending.insert(
            req,
            Pending::Get {
                client,
                key,
                op: GetOp::new(quorum),
                started: self.now,
                participants: replicas,
            },
        );
        self.sync_waiting.insert(req);
        let hop = self.net.client_delay();
        self.push(self.now + hop, Ev::Deliver { to: coordinator, msg: Msg::GetClient { req, key } });
        match self.run_sync(req)? {
            SyncDone::Get { values, ctx } => Ok((values, ctx)),
            SyncDone::Put { .. } => unreachable!("GET request resolved as a PUT"),
        }
    }

    /// Issue one PUT for `client` interactively (see [`Sim::sync_get`]):
    /// `ctx` and `observed` come from the caller's session (the opaque
    /// API token), and ground truth registers with the oracle at issue
    /// time. The returned context is the coordinator's post-write
    /// context, `Some` only when the write left no concurrent siblings
    /// — the one case where chaining a PUT on it is causally sound.
    pub fn sync_put(
        &mut self,
        client: usize,
        key: Key,
        len: u32,
        ctx: &M::Context,
        observed: &[u64],
    ) -> crate::Result<(u64, Option<M::Context>)> {
        let zone = self.pref_zone(client);
        let Some((coordinator, replicas)) = self.pick_coordinator(key, zone) else {
            return Err(crate::Error::Unavailable("no live replica to coordinate".into()));
        };
        self.sync_put_at(client, key, len, ctx, observed, coordinator, replicas)
    }

    /// Pinned variant of [`Sim::sync_put`] (see [`Sim::sync_get_at`]).
    #[allow(clippy::too_many_arguments)]
    fn sync_put_at(
        &mut self,
        client: usize,
        key: Key,
        len: u32,
        ctx: &M::Context,
        observed: &[u64],
        coordinator: NodeId,
        replicas: Vec<NodeId>,
    ) -> crate::Result<(u64, Option<M::Context>)> {
        let quorum = self.scoped_quorum(&replicas, coordinator);
        let val = Val::new(self.next_val, len);
        self.next_val += 1;
        let session = &mut self.sessions[client];
        let meta = WriteMeta {
            client: session.actor,
            physical_us: session.skewed_clock(self.now),
            client_seq: session.next_write_seq(key),
        };
        // ground truth is fixed at issue time by what the client saw
        self.oracle.on_write(meta.client, key, val.id, observed);
        self.written.push((key, val.id));
        let req = self.next_req;
        self.next_req += 1;
        self.push(self.now + OP_TIMEOUT_US, Ev::OpTimeout { req });
        self.pending.insert(
            req,
            Pending::Put { client, key, op: PutOp::new(quorum), started: self.now, val },
        );
        self.sync_waiting.insert(req);
        let hop = self.net.client_delay();
        self.push(
            self.now + hop,
            Ev::Deliver {
                to: coordinator,
                msg: Msg::PutClient { req, key, ctx: ctx.clone(), val, meta },
            },
        );
        match self.run_sync(req)? {
            SyncDone::Put { id, ctx } => Ok((id, ctx)),
            SyncDone::Get { .. } => unreachable!("PUT request resolved as a GET"),
        }
    }

    /// The id the next write will be assigned. A transport keeping
    /// payloads in a side table must record them under this id *before*
    /// calling [`Sim::sync_put`]: a PUT that fails its quorum has often
    /// still been applied at the coordinator (sloppy semantics), and its
    /// value must be resolvable by later GETs.
    pub fn peek_next_val(&self) -> u64 {
        self.next_val
    }

    // ---------------------------------------------------------------
    // synchronous typed CRDT ops (the DES mirror of `server::typed`)
    // ---------------------------------------------------------------
    //
    // Same read-join-mint-mutate-commit RMW as the threaded cluster,
    // with the DES supplying the two serialization guarantees for free:
    // the sync API runs one op to completion at a time (no stripe lock
    // needed), and the coordinator's local state is always reply #1 of
    // the pinned read. The write is pinned to the read's coordinator so
    // a quorum-failed commit still lands the minted dot at the one node
    // whose next read is guaranteed to include it; restarts and wipes —
    // which void that guarantee — bump `typed_epoch` above.

    /// Join the decodable typed payloads behind `vals` (the sibling-join
    /// of `server::typed`): `None` when no sibling carries one. A blob
    /// this table never held is skipped — metadata-only, like a reopened
    /// durable cluster; a present but undecodable one is an error.
    fn typed_join(&self, vals: &[Val]) -> crate::Result<Option<TypedState>> {
        let mut state: Option<TypedState> = None;
        for v in vals {
            let Some(bytes) = self.typed_blobs.get(&v.id) else { continue };
            let sibling = TypedState::decode(bytes)?;
            match &mut state {
                None => state = Some(sibling),
                Some(st) => st.merge(&sibling)?,
            }
        }
        Ok(state)
    }

    /// [`crate::Error::WrongType`] when the joined state exists with
    /// another kind than the op needs.
    fn kind_checked(
        state: Option<TypedState>,
        kind: CrdtKind,
    ) -> crate::Result<Option<TypedState>> {
        match state {
            Some(st) if st.kind() != kind => Err(crate::Error::WrongType {
                expected: kind.name(),
                found: st.kind().name(),
            }),
            other => Ok(other),
        }
    }

    /// The shared read phase of the non-mutating typed ops.
    fn sync_typed_read(
        &mut self,
        client: usize,
        key: Key,
        kind: CrdtKind,
    ) -> crate::Result<Option<TypedState>> {
        let zone = self.pref_zone(client);
        let Some((coordinator, replicas)) = self.pick_coordinator(key, zone) else {
            return Err(crate::Error::Unavailable("no live replica to coordinate".into()));
        };
        let (values, _ctx) = self.sync_get_at(client, key, coordinator, replicas)?;
        Self::kind_checked(self.typed_join(&values)?, kind)
    }

    /// The typed read-modify-write every mutating op runs: pinned
    /// quorum-read + sibling-join, mint under the coordinator's epoch
    /// actor, mutate, commit pinned through the register PUT path.
    fn sync_typed_rmw<R>(
        &mut self,
        client: usize,
        key: Key,
        kind: CrdtKind,
        mutate: impl FnOnce(&mut TypedState, Actor) -> R,
    ) -> crate::Result<R> {
        let zone = self.pref_zone(client);
        let Some((coordinator, replicas)) = self.pick_coordinator(key, zone) else {
            return Err(crate::Error::Unavailable("no live replica to coordinate".into()));
        };
        let (values, ctx) = self.sync_get_at(client, key, coordinator, replicas.clone())?;
        let mut st = match Self::kind_checked(self.typed_join(&values)?, kind)? {
            Some(st) => st,
            None => TypedState::fresh(kind),
        };
        let actor = mint_actor(coordinator, self.nodes[coordinator].typed_epoch);
        let out = mutate(&mut st, actor);
        let bytes = st.encode_to_vec();
        let len = bytes.len() as u32;
        let observed: Vec<u64> = values.iter().map(|v| v.id).collect();
        // the blob goes in the side table *before* the PUT: a
        // quorum-failed write may still have been applied at the
        // coordinator, and later reads must resolve its payload
        self.typed_blobs.insert(self.next_val, bytes);
        self.sync_put_at(client, key, len, &ctx, &observed, coordinator, replicas)?;
        Ok(out)
    }

    /// `SADD` through the DES: add `elem` to the set at `key`,
    /// returning the minted dot (the mirror of
    /// [`crate::server::LocalCluster::set_add`]).
    pub fn sync_sadd(&mut self, client: usize, key: Key, elem: &[u8]) -> crate::Result<Dot> {
        self.sync_typed_rmw(client, key, CrdtKind::Set, |st, actor| {
            let TypedState::Set(s) = st else { unreachable!("kind checked") };
            let dot = s.mint(actor);
            let _delta = s.add(elem.to_vec(), dot);
            dot
        })
    }

    /// `SREM`: remove the *observed* dots of `elem`, returning them
    /// (empty when the element was not present — still a success).
    pub fn sync_srem(&mut self, client: usize, key: Key, elem: &[u8]) -> crate::Result<Vec<Dot>> {
        self.sync_typed_rmw(client, key, CrdtKind::Set, |st, _actor| {
            let TypedState::Set(s) = st else { unreachable!("kind checked") };
            let (dots, _delta) = s.remove(elem);
            dots
        })
    }

    /// `SMEMBERS`: the set's elements, ascending.
    pub fn sync_smembers(&mut self, client: usize, key: Key) -> crate::Result<Vec<Vec<u8>>> {
        match self.sync_typed_read(client, key, CrdtKind::Set)? {
            None => Ok(Vec::new()),
            Some(TypedState::Set(s)) => Ok(s.members().map(|e| e.to_vec()).collect()),
            Some(_) => unreachable!("kind checked"),
        }
    }

    /// `INCR`: apply a signed increment, returning the post-op value.
    pub fn sync_incr(&mut self, client: usize, key: Key, by: i64) -> crate::Result<i64> {
        self.sync_typed_rmw(client, key, CrdtKind::Counter, |st, actor| {
            let TypedState::Counter(c) = st else { unreachable!("kind checked") };
            let _delta = c.incr(actor, by);
            c.value()
        })
    }

    /// `COUNT`: the counter's value (0 for a never-written key).
    pub fn sync_count(&mut self, client: usize, key: Key) -> crate::Result<i64> {
        match self.sync_typed_read(client, key, CrdtKind::Counter)? {
            None => Ok(0),
            Some(TypedState::Counter(c)) => Ok(c.value()),
            Some(_) => unreachable!("kind checked"),
        }
    }

    /// `MPUT`: set `field` to `value` in the map at `key`.
    pub fn sync_mput(
        &mut self,
        client: usize,
        key: Key,
        field: &[u8],
        value: &[u8],
    ) -> crate::Result<Dot> {
        self.sync_typed_rmw(client, key, CrdtKind::Map, |st, actor| {
            let TypedState::Map(m) = st else { unreachable!("kind checked") };
            let dot = m.mint(actor);
            let _delta = m.put(field.to_vec(), value.to_vec(), dot);
            dot
        })
    }

    /// `MGET`: the field's current value, `None` when absent.
    pub fn sync_mget(
        &mut self,
        client: usize,
        key: Key,
        field: &[u8],
    ) -> crate::Result<Option<Vec<u8>>> {
        match self.sync_typed_read(client, key, CrdtKind::Map)? {
            None => Ok(None),
            Some(TypedState::Map(m)) => Ok(m.get(field).map(<[u8]>::to_vec)),
            Some(_) => unreachable!("kind checked"),
        }
    }

    /// The joined typed state `node` currently holds for `key` — what
    /// per-replica convergence assertions compare after [`Sim::settle`].
    pub fn typed_state_at(&self, node: NodeId, key: Key) -> Option<TypedState> {
        let vals = self.nodes[node].store.values(key);
        self.typed_join(&vals).ok().flatten()
    }

    /// Pop events until `req` resolves. The op's timeout event is always
    /// queued, so this terminates even when every message is dropped.
    fn run_sync(&mut self, req: u64) -> crate::Result<SyncDone<M>> {
        loop {
            if let Some(done) = self.sync_done.remove(&req) {
                return done;
            }
            let Some(Reverse(q)) = self.queue.pop() else {
                self.sync_waiting.remove(&req);
                self.pending.remove(&req);
                return Err(crate::Error::Unavailable("simulated op never resolved".into()));
            };
            self.now = q.at;
            self.dispatch(q.ev);
        }
    }

    fn dispatch(&mut self, ev: Ev<M>) {
        match ev {
            Ev::Deliver { to, msg } => {
                if !self.nodes[to].up {
                    return; // crashed nodes drop traffic
                }
                self.on_msg(to, msg);
            }
            Ev::ClientIssue { client, op } => self.issue(client, op),
            Ev::ClientDone { client, req } => {
                // reply reached the client; close the loop
                let _ = req;
                self.schedule_next_op(client, 0);
            }
            Ev::OpTimeout { req } => {
                if let Some(p) = self.pending.remove(&req) {
                    self.metrics.failed_ops += 1;
                    if self.sync_waiting.remove(&req) {
                        // synchronous op: resolve the waiter with the
                        // quorum shortfall instead of closing a loop
                        let (got, needed) = match &p {
                            Pending::Get { op, .. } => (op.replies(), self.quorum.r),
                            Pending::Put { op, .. } => (op.acks(), self.quorum.w),
                        };
                        self.sync_done
                            .insert(req, Err(crate::Error::QuorumNotMet { got, needed }));
                    } else {
                        let client = match p {
                            Pending::Get { client, .. } => client,
                            Pending::Put { client, .. } => client,
                        };
                        self.schedule_next_op(client, 0);
                    }
                }
            }
            Ev::AeTick { node } => self.anti_entropy(node),
            Ev::ShipTick { node } => self.ship(node),
            Ev::ClockSkew { node, delta_us } => {
                if let Some(n) = self.nodes.get_mut(node) {
                    n.skew_us += delta_us;
                }
            }
            Ev::Crash { node } => self.nodes[node].up = false,
            Ev::Recover { node } => {
                self.nodes[node].up = true;
                // a node that was decommissioned while crashed comes
                // back, notices it owns nothing, and drains what it
                // holds — without this its data could strand if the
                // workload (and with it the drain AE ticks) ended first
                if !self.nodes[node].member {
                    self.retiree_handoff(node);
                }
            }
            Ev::PartitionGroups { left, right } => {
                self.net.partition_groups(&left, &right)
            }
            Ev::HealAll => {
                self.net.heal_all();
                // parity with the chaos fabric: a blanket heal also
                // clears injected clock skew
                for n in &mut self.nodes {
                    n.skew_us = 0;
                }
            }
            Ev::Degrade { drop_ppm, extra_delay_us } => {
                self.net.degrade(drop_ppm as f64 / 1_000_000.0, extra_delay_us)
            }
            Ev::Join => self.on_join(),
            Ev::Decommission { node } => self.on_decommission(node),
            Ev::Restart { node } => self.on_restart(node),
            Ev::Wipe { node } => {
                let n = &mut self.nodes[node];
                n.store = KeyStore::new(self.mech.clone());
                n.synced.clear();
                n.unsynced.clear();
                // total state loss: typed mints must move to a fresh actor
                n.typed_epoch += 1;
            }
        }
    }

    /// Process death + recovery: drop the unsynced WAL tail, rebuild the
    /// store from the persisted per-key states (the same last-record-
    /// wins outcome `DurableBackend`'s replay produces).
    fn on_restart(&mut self, node: NodeId) {
        let mech = self.mech.clone();
        let n = &mut self.nodes[node];
        n.unsynced.clear();
        let store = KeyStore::new(mech);
        for (k, st) in &n.synced {
            store.merge_key(*k, st);
        }
        n.store = store;
        // the unsynced tail may have held this node's freshest typed
        // mints; reusing their counters after the rollback would
        // false-cover concurrent adds — move to a fresh actor epoch
        n.typed_epoch += 1;
    }

    /// Record `key`'s post-state in the node's logical WAL tail and fold
    /// the tail into the persisted map every `flush_every_ops` mutations.
    /// The single funnel for the DES durability model — called by every
    /// store mutation.
    fn log_durable(&mut self, node: NodeId, key: Key) {
        let every = self.cfg.durability.flush_every_ops;
        if every == 0 {
            return; // model off: volatile node, zero bookkeeping
        }
        let state = self.nodes[node].store.state(key);
        let n = &mut self.nodes[node];
        n.unsynced.push((key, state));
        if n.unsynced.len() >= every as usize {
            // "fsync": the tail reaches disk, in order (last wins)
            for (k, st) in n.unsynced.drain(..) {
                n.synced.insert(k, st);
            }
        }
    }

    // ---------------------------------------------------------------
    // elastic membership
    // ---------------------------------------------------------------

    /// Admit a new node: allocate the next dense id, place its vnodes,
    /// bump the epoch, and re-home affected ranges — each member pushes
    /// the states of keys now homed at the newcomer through one AE-style
    /// message (so chaos on the links applies; periodic anti-entropy
    /// catches whatever a drop roll eats).
    fn on_join(&mut self) {
        let id = self.nodes.len();
        let fresh = SimNode::fresh(&self.mech);
        self.nodes.push(fresh);
        let rid = self.ring.add_node();
        debug_assert_eq!(rid, id);
        self.epoch += 1;
        for m in 0..id {
            if !self.nodes[m].member || !self.nodes[m].up {
                continue;
            }
            let keys: Vec<Key> = self.nodes[m].store.keys().collect();
            let states: Vec<(Key, M::State)> = keys
                .into_iter()
                .filter(|&k| self.replicas(k).contains(&id))
                .map(|k| (k, self.nodes[m].store.state(k)))
                .collect();
            if states.is_empty() {
                continue;
            }
            self.metrics.ae_keys_synced += states.len() as u64;
            self.send(m, id, Msg::AePush { states });
        }
        if self.cfg.antientropy.period_us > 0 {
            let jitter = self.rng.below(self.cfg.antientropy.period_us.max(1));
            self.push(self.now + jitter, Ev::AeTick { node: id });
        }
        if self.geo() && self.cfg.geo.ship_interval_us > 0 {
            let jitter = self.rng.below(self.cfg.geo.ship_interval_us.max(1));
            self.push(self.now + jitter, Ev::ShipTick { node: id });
        }
    }

    /// Retire a member: remove its vnodes (keys re-route to successors),
    /// bump the epoch, and hand off every key it holds to the key's new
    /// homes through the network. A crashed retiree hands off nothing
    /// *now* — the handoff replays when it recovers (see the
    /// [`Ev::Recover`] dispatch), mirroring the threaded cluster where
    /// such a sweep parks hints that drain once the retiree is back —
    /// so one churn schedule reaches the same verdict in both worlds
    /// even when a crash window swallows the decommission instant.
    fn on_decommission(&mut self, node: NodeId) {
        if node >= self.nodes.len() || !self.nodes[node].member {
            return;
        }
        // quorum floor, mirroring `LocalCluster::decommission_node`: a
        // refusal there must be a refusal here too (no epoch bump), or
        // one churn schedule would leave the two worlds with divergent
        // membership
        let remaining = self.nodes.iter().filter(|n| n.member).count() - 1;
        if remaining < self.quorum.r.max(self.quorum.w) {
            return;
        }
        self.nodes[node].member = false;
        self.ring.remove_node(node);
        self.epoch += 1;
        if self.nodes[node].up {
            self.retiree_handoff(node);
        }
    }

    /// Push everything `node` (a retiree) holds to each key's current
    /// homes through the network.
    fn retiree_handoff(&mut self, node: NodeId) {
        let keys: Vec<Key> = self.nodes[node].store.keys().collect();
        for k in keys {
            let state = self.nodes[node].store.state(k);
            for home in self.replicas(k) {
                self.metrics.ae_keys_synced += 1;
                self.send(node, home, Msg::StatePush { key: k, state: state.clone() });
            }
        }
    }

    // ---------------------------------------------------------------
    // client op entry
    // ---------------------------------------------------------------

    /// Preference list plus the coordinating replica (first live node,
    /// or a random live one under `random_coordinator`); `None` when
    /// every replica is down. With `zone` set (geo mode), a live replica
    /// in the client's own DC coordinates when one exists — this is what
    /// keeps both halves of a DC partition serving their local users.
    fn pick_coordinator(
        &mut self,
        key: Key,
        zone: Option<usize>,
    ) -> Option<(NodeId, Vec<NodeId>)> {
        let replicas = self.replicas(key);
        let live: Vec<NodeId> =
            replicas.iter().copied().filter(|&n| self.nodes[n].up).collect();
        if live.is_empty() {
            return None;
        }
        if let Some(z) = zone {
            if let Some(&local) = live.iter().find(|&&n| self.zone_of(n) == z) {
                return Some((local, replicas));
            }
        }
        if self.cfg.cluster.random_coordinator {
            Some((live[self.rng.below(live.len() as u64) as usize], replicas))
        } else {
            Some((live[0], replicas))
        }
    }

    /// The coordinator-preference zone for `client`: its home DC in geo
    /// mode, no preference when flat.
    fn pref_zone(&self, client: usize) -> Option<usize> {
        if self.geo() {
            Some(self.client_zone(client))
        } else {
            None
        }
    }

    fn issue(&mut self, client: usize, op: Op) {
        let zone = self.pref_zone(client);
        let Some((coordinator, replicas)) = self.pick_coordinator(op.key, zone) else {
            self.metrics.failed_ops += 1;
            self.schedule_next_op(client, 1000);
            return;
        };
        let quorum = self.scoped_quorum(&replicas, coordinator);
        let req = self.next_req;
        self.next_req += 1;
        self.push(self.now + OP_TIMEOUT_US, Ev::OpTimeout { req });
        let hop = self.net.client_delay();
        match op.kind {
            OpKind::Get => {
                self.pending.insert(
                    req,
                    Pending::Get {
                        client,
                        key: op.key,
                        op: GetOp::new(quorum),
                        started: self.now,
                        participants: replicas,
                    },
                );
                self.push(
                    self.now + hop,
                    Ev::Deliver { to: coordinator, msg: Msg::GetClient { req, key: op.key } },
                );
            }
            OpKind::Put { len } => {
                let val = Val::new(self.next_val, len);
                self.next_val += 1;
                let session = &mut self.sessions[client];
                let ctx = session.context_for(op.key);
                let observed = session.observed_for(op.key);
                let meta = WriteMeta {
                    client: session.actor,
                    physical_us: session.skewed_clock(self.now),
                    client_seq: session.next_write_seq(op.key),
                };
                // ground truth is fixed at issue time by what the client saw
                self.oracle.on_write(session.actor, op.key, val.id, &observed);
                self.written.push((op.key, val.id));
                self.pending.insert(
                    req,
                    Pending::Put {
                        client,
                        key: op.key,
                        op: PutOp::new(quorum),
                        started: self.now,
                        val,
                    },
                );
                self.push(
                    self.now + hop,
                    Ev::Deliver {
                        to: coordinator,
                        msg: Msg::PutClient { req, key: op.key, ctx, val, meta },
                    },
                );
            }
        }
    }

    // ---------------------------------------------------------------
    // node message handling
    // ---------------------------------------------------------------

    fn on_msg(&mut self, node: NodeId, msg: Msg<M>) {
        match msg {
            Msg::GetClient { req, key } => {
                let Some(Pending::Get { participants, .. }) = self.pending.get(&req) else {
                    return; // timed out
                };
                let participants = participants.clone();
                for &replica in &participants {
                    if replica == node {
                        let state = self.nodes[node].store.state(key);
                        self.on_get_reply(node, req, state);
                    } else {
                        self.send(node, replica, Msg::GetSub { req, key, from: node });
                    }
                }
            }
            Msg::GetSub { req, key, from } => {
                let state = self.nodes[node].store.state(key);
                self.send(node, from, Msg::GetSubResp { req, state });
            }
            Msg::GetSubResp { req, state } => self.on_get_reply(node, req, state),
            Msg::PutClient { req, key, ctx, val, meta } => {
                // §4.1 put steps 2–3: update + local sync at the coordinator
                self.store_write(node, key, &ctx, val, &meta);
                let pt = self.phys(node);
                self.nodes[node].hlc.now(pt);
                let state = self.nodes[node].store.state(key);
                let replicas = self.replicas(key);
                let geo = self.geo();
                let my_zone = self.zone_of(node);
                let Some(Pending::Put { op, client, started, .. }) =
                    self.pending.get_mut(&req)
                else {
                    return;
                };
                let (client, started) = (*client, *started);
                if op.satisfied_immediately() {
                    self.complete_put(req, client, key, started, val, node);
                }
                for replica in replicas {
                    if replica == node {
                        continue;
                    }
                    if geo && self.zone_of(replica) != my_zone {
                        // remote-DC home: fed asynchronously by the
                        // shipper, never counted toward W
                        if !self.nodes[node].ship.contains(&key) {
                            self.nodes[node].ship.push(key);
                        }
                    } else {
                        self.send(
                            node,
                            replica,
                            Msg::Replicate { req, key, state: state.clone(), from: node },
                        );
                    }
                }
            }
            Msg::Replicate { req, key, state, from } => {
                self.store_merge(node, key, &state);
                self.send(node, from, Msg::ReplicateAck { req });
            }
            Msg::ReplicateAck { req } => {
                let Some(Pending::Put { op, client, key, started, val }) =
                    self.pending.get_mut(&req)
                else {
                    return;
                };
                let (client, key, started, val) = (*client, *key, *started, *val);
                if op.on_ack() {
                    // a ReplicateAck is addressed to the coordinator, so
                    // `node` is the coordinating replica here
                    self.complete_put(req, client, key, started, val, node);
                }
            }
            Msg::StatePush { key, state } => {
                self.store_merge(node, key, &state);
            }
            Msg::AePull { keys, from } => {
                // respond only with keys this node actually holds:
                // manufacturing default states for absent keys would
                // materialize empty entries at the puller (a merge with
                // a default state is a no-op on values but would skew
                // the hash trees' key sets)
                let states: Vec<(Key, M::State)> = keys
                    .iter()
                    .filter_map(|&k| {
                        self.nodes[node]
                            .store
                            .backend()
                            .with_state(k, |st| st.cloned().map(|st| (k, st)))
                    })
                    .collect();
                if !states.is_empty() {
                    self.send(node, from, Msg::AePush { states });
                }
            }
            Msg::AePush { states } => {
                self.metrics.ae_keys_synced += states.len() as u64;
                for (key, state) in states {
                    self.store_merge(node, key, &state);
                }
            }
            Msg::ShipBatch { states, ts } => {
                // HLC recv-merge first: every state this batch carries is
                // causally behind the batch timestamp
                let pt = self.phys(node);
                self.nodes[node].hlc.recv(pt, ts);
                for (key, state) in states {
                    self.store_merge(node, key, &state);
                }
            }
        }
    }

    fn on_get_reply(&mut self, coordinator: NodeId, req: u64, state: M::State) {
        let Some(Pending::Get { op, client, key, started, participants, .. }) =
            self.pending.get_mut(&req)
        else {
            return;
        };
        let (client, key, started) = (*client, *key, *started);
        let participants = participants.clone();
        let answer = op.on_reply(&self.mech, &state);
        let all_in = op.replies() == participants.len();
        let repair_state = if all_in { Some(op.merged().clone()) } else { None };

        if let Some(res) = answer {
            if self.sync_waiting.remove(&req) {
                self.sync_done.insert(
                    req,
                    Ok(SyncDone::Get {
                        values: res.values.clone(),
                        ctx: res.context.clone(),
                    }),
                );
            }
            // answer the client
            let ids: Vec<u64> = res.values.iter().map(|v| v.id).collect();
            let (fc, tc) = self.oracle.classify_siblings(&ids);
            self.metrics.false_concurrent_pairs += fc;
            self.metrics.true_concurrent_pairs += tc;
            self.metrics.max_siblings = self.metrics.max_siblings.max(ids.len());
            self.metrics.context_bytes += self.mech.context_bytes(&res.context) as u64;
            self.sessions[client].on_get(key, res.context, ids);
            self.metrics.gets += 1;
            self.metrics.get_latency.record(self.now - started);
            let hop = self.net.client_delay();
            self.push(self.now + hop, Ev::ClientDone { client, req });
        }
        if let Some(merged) = repair_state {
            // read repair: push the reduced state back to all replicas
            self.pending.remove(&req);
            for replica in participants {
                if replica == coordinator {
                    self.store_merge(coordinator, key, &merged);
                } else {
                    self.send(
                        coordinator,
                        replica,
                        Msg::StatePush { key, state: merged.clone() },
                    );
                }
            }
        }
    }

    fn complete_put(
        &mut self,
        req: u64,
        client: usize,
        key: Key,
        started: u64,
        val: Val,
        coordinator: NodeId,
    ) {
        self.metrics.puts += 1;
        self.metrics.put_latency.record(self.now - started);
        self.acked.push((key, val.id));
        // the DES client reply carries no body, so the session context is
        // simply consumed (the closed-loop behavior the figure replays
        // and E6/E9 depend on)
        self.sessions[client].on_put_complete(key, &PutResult { id: val.id, ctx: None });
        if self.sync_waiting.remove(&req) {
            // synchronous API waiters get the coordinator's post-write
            // context (see `crate::api::PutReply`) — but only when the
            // write left no concurrent siblings: a survivor's events are
            // in the state context without the client having observed
            // them, so chaining on it would destroy a concurrent write
            let state = self.nodes[coordinator].store.state(key);
            let (vals, ctx) = self.mech.read(&state);
            let ctx = (vals.len() == 1 && vals[0].id == val.id).then_some(ctx);
            self.sync_done.insert(req, Ok(SyncDone::Put { id: val.id, ctx }));
        }
        let hop = self.net.client_delay();
        self.push(self.now + hop, Ev::ClientDone { client, req });
        // leave the Pending entry for late acks only if W < N; timeout
        // cleans it up. Simpler: drop it now — late acks are ignored.
        self.pending.remove(&req);
    }

    // ---------------------------------------------------------------
    // store mutation with oracle-checked anomaly accounting
    // ---------------------------------------------------------------

    fn store_write(&mut self, node: NodeId, key: Key, ctx: &M::Context, val: Val, meta: &WriteMeta) {
        let before: Vec<u64> =
            self.nodes[node].store.values(key).iter().map(|v| v.id).collect();
        self.nodes[node].store.write(key, ctx, val, Actor::server(node as u32), meta);
        self.account_drops(node, key, &before);
        self.log_durable(node, key);
    }

    fn store_merge(&mut self, node: NodeId, key: Key, incoming: &M::State) {
        let before: Vec<u64> =
            self.nodes[node].store.values(key).iter().map(|v| v.id).collect();
        self.nodes[node].store.merge_key(key, incoming);
        self.account_drops(node, key, &before);
        self.log_durable(node, key);
    }

    fn account_drops(&mut self, node: NodeId, key: Key, before: &[u64]) {
        let after: Vec<u64> =
            self.nodes[node].store.values(key).iter().map(|v| v.id).collect();
        self.metrics.max_siblings = self.metrics.max_siblings.max(after.len());
        for &dropped in before.iter().filter(|id| !after.contains(id)) {
            match self.oracle.classify_drop(dropped, &after) {
                DropVerdict::CorrectSupersession => self.metrics.correct_supersessions += 1,
                DropVerdict::LostUpdate => self.metrics.lost_updates += 1,
            }
        }
    }

    // ---------------------------------------------------------------
    // anti-entropy
    // ---------------------------------------------------------------

    fn anti_entropy(&mut self, node: NodeId) {
        let period = self.cfg.antientropy.period_us;
        if period == 0 || self.workload_done() {
            return;
        }
        // reschedule first so crashes don't cancel the timer forever
        let jitter = self.rng.below(period / 4 + 1);
        self.push(self.now + period + jitter, Ev::AeTick { node });
        if !self.nodes[node].up || self.nodes.len() < 2 {
            return;
        }
        // pick a random peer among the *other members* (a decommissioned
        // node is never a peer: it must drain, not accumulate)
        let peers: Vec<NodeId> = (0..self.nodes.len())
            .filter(|&m| m != node && self.nodes[m].member)
            .collect();
        if peers.is_empty() {
            return;
        }
        let peer = if self.geo() {
            // AE stays mostly intra-DC; with probability
            // `geo.cross_dc_ae_prob` a round reaches across DCs — the
            // low-frequency backstop that repairs what shipper batches
            // lost to the network
            let my_zone = self.zone_of(node);
            let cross = self.rng.f64() < self.cfg.geo.cross_dc_ae_prob;
            let scoped: Vec<NodeId> = peers
                .iter()
                .copied()
                .filter(|&m| (self.zone_of(m) != my_zone) == cross)
                .collect();
            let pool = if scoped.is_empty() { &peers } else { &scoped };
            pool[self.rng.below(pool.len() as u64) as usize]
        } else {
            peers[self.rng.below(peers.len() as u64) as usize]
        };
        if !self.nodes[peer].up {
            return;
        }
        self.metrics.ae_rounds += 1;
        // Build the exchange worklist: with `antientropy.merkle` (the
        // default) walk the two stores' incremental hash trees and touch
        // only keys under diverged subtrees — a quiesced pair exchanges
        // nothing; with the scan path, every local key is shipped. Then
        // push the listed states to the peer, and — for members — pull
        // its copies back. A decommissioned node runs push-only ticks:
        // it keeps draining what it still holds toward the members until
        // the run ends, but takes in nothing new.
        let keys: Vec<Key> = if self.cfg.antientropy.merkle {
            // both stores are single-shard in-memory backends, so the
            // shard-0 trees cover the whole stores (the walk stands in
            // for the digest exchange a wire protocol would run)
            let sa = self.nodes[node].store.backend();
            let sb = self.nodes[peer].store.backend();
            let (mut keys, stats) = sa
                .with_merkle(0, |ta| sb.with_merkle(0, |tb| crate::antientropy::merkle::diff(ta, tb)));
            self.metrics.ae_digests_compared += stats.nodes_compared;
            keys.sort_unstable();
            keys
        } else {
            self.nodes[node].store.keys().collect()
        };
        let states: Vec<(Key, M::State)> = keys
            .iter()
            .filter_map(|&k| {
                // ship only keys this node holds; peer-only divergence
                // comes back via the pull
                self.nodes[node]
                    .store
                    .backend()
                    .with_state(k, |st| st.cloned().map(|st| (k, st)))
            })
            .collect();
        self.metrics.ae_keys_synced += states.len() as u64;
        if !states.is_empty() {
            self.send(node, peer, Msg::AePush { states });
        }
        if self.nodes[node].member && !keys.is_empty() {
            self.send(node, peer, Msg::AePull { keys, from: node });
        }
    }

    // ---------------------------------------------------------------
    // cross-DC shipper
    // ---------------------------------------------------------------

    /// Drain `node`'s cross-DC ship buffer: snapshot the *current* state
    /// of every parked key, stamp the batch with a fresh HLC send event,
    /// and push one `ShipBatch` per remote-DC home that needs one. Runs
    /// every `geo.ship_interval_us`; a batch lost to the network is
    /// repaired by the cross-DC AE backstop.
    fn ship(&mut self, node: NodeId) {
        let interval = self.cfg.geo.ship_interval_us;
        if !self.geo() || interval == 0 {
            return;
        }
        if !self.workload_done() {
            // reschedule first so crashes don't cancel the timer forever
            let jitter = self.rng.below(interval / 4 + 1);
            self.push(self.now + interval + jitter, Ev::ShipTick { node });
        }
        if !self.nodes[node].up || self.nodes[node].ship.is_empty() {
            return;
        }
        let keys = std::mem::take(&mut self.nodes[node].ship);
        let my_zone = self.zone_of(node);
        let pt = self.phys(node);
        let ts = self.nodes[node].hlc.now(pt);
        // BTreeMap: deterministic destination order (a HashMap here
        // would reorder sends across runs and break seeded replays)
        let mut per_dest: BTreeMap<NodeId, Vec<(Key, M::State)>> = BTreeMap::new();
        for k in keys {
            let state = self.nodes[node].store.state(k);
            for home in self.replicas(k) {
                if self.zone_of(home) != my_zone {
                    per_dest.entry(home).or_default().push((k, state.clone()));
                }
            }
        }
        for (dest, states) in per_dest {
            self.metrics.ship_batches += 1;
            self.metrics.ship_keys += states.len() as u64;
            self.send(node, dest, Msg::ShipBatch { states, ts });
        }
    }

    // ---------------------------------------------------------------
    // final accounting
    // ---------------------------------------------------------------

    fn finalize_metrics(&mut self) {
        self.metrics.metadata_bytes =
            self.nodes.iter().map(|n| n.store.metadata_bytes()).sum();
    }

    /// Post-run audit: a written value is **permanently lost** when no
    /// surviving value on an **active member** causally covers it (E6's
    /// headline number). Copies stranded on a decommissioned node do not
    /// count as survivors: its keys must have been re-homed.
    pub fn audit_permanently_lost(&self) -> u64 {
        self.permanently_lost_among(&self.written)
    }

    /// Like [`audit_permanently_lost`](Sim::audit_permanently_lost) but
    /// over **acknowledged** writes only — the invariant that must hold
    /// even under restarts with state loss and wipes, where an *issued*
    /// write that never reached its quorum may legitimately die with the
    /// only replica that saw it.
    pub fn audit_acked_lost(&self) -> u64 {
        self.permanently_lost_among(&self.acked)
    }

    /// Writes acknowledged to clients during the run.
    pub fn writes_acked(&self) -> u64 {
        self.acked.len() as u64
    }

    fn permanently_lost_among(&self, written: &[(Key, u64)]) -> u64 {
        let mut survivors: HashMap<Key, Vec<u64>> = HashMap::new();
        for n in self.nodes.iter().filter(|n| n.member) {
            for key in n.store.keys() {
                let entry = survivors.entry(key).or_default();
                for v in n.store.values(key) {
                    if !entry.contains(&v.id) {
                        entry.push(v.id);
                    }
                }
            }
        }
        let empty = Vec::new();
        written
            .iter()
            .filter(|(key, id)| {
                let surv = survivors.get(key).unwrap_or(&empty);
                !surv.iter().any(|&s| s == *id || self.oracle.leq(*id, s))
            })
            .count() as u64
    }

    /// Total writes issued during the run.
    pub fn writes_issued(&self) -> u64 {
        self.written.len() as u64
    }

    /// Force-merge every node pairwise until quiescent (test helper that
    /// models "eventual" delivery after the run). Any up node — member or
    /// draining decommissioned — sources states, but only up *members*
    /// receive them: retirement is a one-way valve.
    pub fn settle(&mut self) {
        for _ in 0..self.nodes.len() {
            for a in 0..self.nodes.len() {
                for b in 0..self.nodes.len() {
                    if a == b
                        || !self.nodes[a].up
                        || !self.nodes[b].up
                        || !self.nodes[b].member
                    {
                        continue;
                    }
                    let keys: Vec<Key> = self.nodes[a].store.keys().collect();
                    for key in keys {
                        let st = self.nodes[a].store.state(key);
                        self.store_merge(b, key, &st);
                    }
                }
            }
        }
        self.finalize_metrics();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::mechs::{DvvMech, LwwMech, ServerVvMech};
    use crate::workload::{RandomWorkload, WorkloadSpec};

    fn cfg(nodes: usize, n: usize, r: usize, w: usize) -> StoreConfig {
        let mut c = StoreConfig::default();
        c.cluster.nodes = nodes;
        c.cluster.replication = n;
        c.cluster.read_quorum = r;
        c.cluster.write_quorum = w;
        c
    }

    fn small_workload(clients: usize, ops: u64) -> Box<RandomWorkload> {
        Box::new(RandomWorkload::new(
            WorkloadSpec {
                keys: 20,
                ops_per_client: ops,
                put_fraction: 0.6,
                read_before_write: 0.6,
                mean_think_us: 500.0,
                ..Default::default()
            },
            clients,
        ))
    }

    #[test]
    fn dvv_run_completes_without_lost_updates() {
        let mut sim = Sim::new(
            DvvMech,
            cfg(5, 3, 2, 2),
            8,
            true,
            small_workload(8, 40),
            42,
        )
        .unwrap();
        sim.start();
        sim.run(u64::MAX);
        assert!(sim.metrics.ops() > 200, "{}", sim.metrics.summary());
        assert_eq!(sim.metrics.failed_ops, 0);
        assert_eq!(sim.metrics.lost_updates, 0, "{}", sim.metrics.summary());
        sim.settle();
        assert_eq!(sim.audit_permanently_lost(), 0);
    }

    #[test]
    fn lww_run_loses_concurrent_updates() {
        let mut sim = Sim::new(
            LwwMech,
            cfg(5, 3, 2, 2),
            8,
            true,
            small_workload(8, 40),
            42,
        )
        .unwrap();
        sim.start();
        sim.run(u64::MAX);
        sim.settle();
        assert!(
            sim.audit_permanently_lost() > 0,
            "LWW must lose concurrent updates: {}",
            sim.metrics.summary()
        );
    }

    #[test]
    fn server_vv_loses_same_server_concurrency() {
        // plenty of blind writes to few keys: §3.2's anomaly shows up
        let wl = Box::new(RandomWorkload::new(
            WorkloadSpec {
                keys: 4,
                ops_per_client: 40,
                put_fraction: 0.9,
                read_before_write: 0.1,
                mean_think_us: 200.0,
                ..Default::default()
            },
            8,
        ));
        let mut sim = Sim::new(ServerVvMech, cfg(4, 2, 1, 1), 8, true, wl, 7).unwrap();
        sim.start();
        sim.run(u64::MAX);
        sim.settle();
        assert!(
            sim.audit_permanently_lost() > 0,
            "server-VV must linearize same-server concurrency: {}",
            sim.metrics.summary()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut sim = Sim::new(
                DvvMech,
                cfg(4, 3, 2, 2),
                4,
                true,
                small_workload(4, 20),
                seed,
            )
            .unwrap();
            sim.start();
            sim.run(u64::MAX);
            (sim.metrics.ops(), sim.metrics.messages, sim.now())
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }

    #[test]
    fn crash_and_recover_failover() {
        let mut sim = Sim::new(
            DvvMech,
            cfg(4, 3, 2, 2),
            4,
            true,
            small_workload(4, 30),
            11,
        )
        .unwrap();
        sim.schedule_crash(1_000, 0);
        sim.schedule_recover(400_000, 0);
        sim.start();
        sim.run(u64::MAX);
        // ops still complete (failover to other replicas); no data loss
        assert!(sim.metrics.ops() > 50, "{}", sim.metrics.summary());
        sim.settle();
        assert_eq!(sim.audit_permanently_lost(), 0, "{}", sim.metrics.summary());
    }

    #[test]
    fn partition_with_antientropy_converges() {
        let mut c = cfg(4, 2, 1, 1);
        c.antientropy.period_us = 20_000;
        let mut sim = Sim::new(DvvMech, c, 4, true, small_workload(4, 25), 13).unwrap();
        sim.schedule_partition(5_000, vec![0, 1], vec![2, 3]);
        sim.schedule_heal(150_000);
        sim.start();
        sim.run(2_000_000);
        sim.settle();
        assert!(sim.metrics.ae_rounds > 0);
        assert_eq!(sim.audit_permanently_lost(), 0, "{}", sim.metrics.summary());
    }

    #[test]
    fn degrade_window_drops_messages_without_losing_updates() {
        let mut c = cfg(4, 3, 1, 1);
        c.antientropy.period_us = 20_000;
        let mut sim = Sim::new(DvvMech, c, 4, true, small_workload(4, 30), 19).unwrap();
        crate::sim::failure::FaultPlan::new()
            .degrade_window(0.5, 200, 5_000, 400_000)
            .apply(&mut sim);
        sim.start();
        sim.run(5_000_000);
        assert!(sim.metrics.dropped_messages > 0, "degrade window must drop");
        sim.settle();
        assert_eq!(sim.audit_permanently_lost(), 0, "{}", sim.metrics.summary());
    }

    /// The interactive API: issue ops one at a time, no driver loop.
    struct NoDriver;
    impl Driver for NoDriver {
        fn next_op(&mut self, _c: usize, _now: u64, _rng: &mut Rng) -> Option<Op> {
            None
        }
    }

    #[test]
    fn sync_ops_roundtrip_and_supersede() {
        let mut sim =
            Sim::new(DvvMech, cfg(3, 3, 2, 2), 2, true, Box::new(NoDriver), 5).unwrap();
        // first write on a fresh key: no siblings -> chainable context
        let (id1, post1) = sim.sync_put(0, 7, 8, &Default::default(), &[]).unwrap();
        assert!(post1.is_some(), "lone write returns its post-write context");
        // a second blind write makes siblings -> NO chainable context
        // (it would cover the concurrent write the client never saw)
        let (id2, post2) = sim.sync_put(1, 7, 8, &Default::default(), &[]).unwrap();
        assert_ne!(id1, id2);
        assert!(post2.is_none(), "surviving sibling suppresses the context");
        let (values, ctx) = sim.sync_get(0, 7).unwrap();
        assert_eq!(values.len(), 2, "blind writes are concurrent");
        // informed write with the GET's context supersedes both
        let observed: Vec<u64> = values.iter().map(|v| v.id).collect();
        let (id3, post) = sim.sync_put(0, 7, 8, &ctx, &observed).unwrap();
        let (after, _) = sim.sync_get(0, 7).unwrap();
        assert_eq!(after.len(), 1);
        assert_eq!(after[0].id, id3);
        assert!(post.is_some(), "supersession leaves no siblings: chainable");
        assert_eq!(sim.metrics.lost_updates, 0);
        assert_eq!(sim.metrics.gets, 2);
        assert_eq!(sim.metrics.puts, 3);
    }

    #[test]
    fn sync_ops_fail_cleanly_when_all_replicas_down() {
        let mut sim =
            Sim::new(DvvMech, cfg(3, 3, 2, 2), 1, true, Box::new(NoDriver), 6).unwrap();
        for n in 0..3 {
            sim.nodes[n].up = false;
        }
        assert!(matches!(
            sim.sync_get(0, 1),
            Err(crate::Error::Unavailable(_))
        ));
        assert!(matches!(
            sim.sync_put(0, 1, 4, &Default::default(), &[]),
            Err(crate::Error::Unavailable(_))
        ));
        for n in 0..3 {
            sim.nodes[n].up = true;
        }
        sim.sync_put(0, 1, 4, &Default::default(), &[]).unwrap();
        assert_eq!(sim.sync_get(0, 1).unwrap().0.len(), 1);
    }

    #[test]
    fn join_rebalances_and_decommission_rehomes_without_loss() {
        let mut c = cfg(4, 3, 2, 2);
        c.antientropy.period_us = 20_000;
        let mut sim = Sim::new(DvvMech, c, 4, true, small_workload(4, 30), 23).unwrap();
        sim.schedule_join(30_000);
        sim.schedule_decommission(120_000, 1);
        sim.start();
        sim.run(u64::MAX);
        assert_eq!(
            sim.topology_epoch(),
            crate::cluster::topology::INITIAL_EPOCH + 2,
            "one join + one decommission = two epoch bumps"
        );
        assert_eq!(sim.nodes.len(), 5, "joined node allocated the next dense id");
        assert_eq!(sim.members(), vec![0, 2, 3, 4]);
        assert!(!sim.ring.replicas_for(7, 5).contains(&1), "retiree owns no ranges");
        sim.settle();
        assert_eq!(sim.audit_permanently_lost(), 0, "{}", sim.metrics.summary());
        // handoff completeness: everything the retiree still holds is
        // causally covered by what the members hold
        let retiree_keys: Vec<Key> = sim.nodes[1].store.keys().collect();
        for key in retiree_keys {
            for v in sim.nodes[1].store.values(key) {
                let covered = sim.members().iter().any(|&m| {
                    sim.nodes[m]
                        .store
                        .values(key)
                        .iter()
                        .any(|s| s.id == v.id || sim.oracle.leq(v.id, s.id))
                });
                assert!(covered, "value {} on key {key} was not re-homed", v.id);
            }
        }
        // the newcomer actually serves data
        assert!(sim.nodes[4].store.key_count() > 0, "joined node got its ranges");
    }

    #[test]
    fn decommission_during_crash_drains_on_recovery() {
        // the decommission fires inside a crash window, so the handoff
        // cannot run then; the retiree must drain when it recovers —
        // even after the workload (and its AE ticks) has ended
        let mut c = cfg(4, 3, 2, 2);
        c.antientropy.period_us = 0; // only the recovery drain can re-home
        let mut sim = Sim::new(DvvMech, c, 1, true, small_workload(1, 5), 29).unwrap();
        // seed node 1 with a value no other node holds
        let k = 7u64;
        let (_, ctx) = sim.nodes[1].store.read(k);
        sim.nodes[1].store.write(
            k,
            &ctx,
            Val::new(999, 1),
            Actor::server(1),
            &WriteMeta::basic(Actor::client(9)),
        );
        sim.schedule_crash(1_000, 1);
        sim.schedule_decommission(2_000, 1);
        sim.schedule_recover(3_000_000, 1); // long after the clients retire
        sim.start();
        sim.run(u64::MAX);
        assert!(!sim.members().contains(&1), "decommission applied while crashed");
        let covered = sim
            .members()
            .iter()
            .any(|&m| sim.nodes[m].store.values(k).iter().any(|v| v.id == 999));
        assert!(covered, "recovery drain re-homed the stranded value");
    }

    #[test]
    fn sim_decommission_respects_the_quorum_floor() {
        // parity with LocalCluster::decommission_node: a retirement that
        // would leave fewer members than the quorum needs is refused,
        // with no epoch bump, so one plan ends in the same membership
        // in both worlds
        let mut sim =
            Sim::new(DvvMech, cfg(3, 3, 2, 2), 2, true, small_workload(2, 10), 37).unwrap();
        sim.schedule_decommission(1_000, 0); // 3 -> 2 members: allowed
        sim.schedule_decommission(2_000, 1); // would leave 1 < max(R, W): refused
        sim.start();
        sim.run(u64::MAX);
        assert_eq!(sim.members(), vec![1, 2]);
        assert_eq!(sim.topology_epoch(), crate::cluster::topology::INITIAL_EPOCH + 1);
    }

    #[test]
    fn decommission_of_unknown_or_retired_node_is_ignored() {
        let mut sim = Sim::new(
            DvvMech,
            cfg(3, 2, 1, 1),
            2,
            true,
            small_workload(2, 10),
            31,
        )
        .unwrap();
        sim.schedule_decommission(1_000, 9); // unknown id
        sim.schedule_decommission(2_000, 0);
        sim.schedule_decommission(3_000, 0); // already retired
        sim.start();
        sim.run(u64::MAX);
        assert_eq!(sim.topology_epoch(), crate::cluster::topology::INITIAL_EPOCH + 1);
        assert_eq!(sim.members(), vec![1, 2]);
    }

    #[test]
    fn restart_rolls_back_to_the_persisted_prefix() {
        // no driver: mutate nodes through the sync API so the exact
        // flush boundary is controlled
        let mut cfg = cfg(3, 3, 3, 3);
        cfg.durability.flush_every_ops = 4;
        let mut sim = Sim::new(DvvMech, cfg, 1, true, Box::new(NoDriver), 3).unwrap();
        // W = N = 3: each sync_put mutates all three nodes (coordinator
        // write + two replica merges), so each put advances every node's
        // wal by one entry
        for key in 0..6u64 {
            sim.sync_put(0, key, 4, &Default::default(), &[]).unwrap();
        }
        for n in 0..3 {
            assert_eq!(sim.nodes[n].synced.len(), 4, "flush-every-4: 4 keys on disk");
            assert_eq!(sim.nodes[n].unsynced.len(), 2, "2-mutation unsynced tail");
        }
        let now = sim.now();
        sim.schedule_restart(now + 1, 0);
        sim.run(now + 10);
        // node 0 kept the 4 synced mutations, lost the 2-entry tail
        assert_eq!(sim.nodes[0].store.key_count(), 4);
        assert!(sim.nodes[0].unsynced.is_empty(), "the tail died with the process");
        // ...but every acked write survives on the other replicas
        sim.settle();
        assert_eq!(sim.audit_acked_lost(), 0);
        assert_eq!(sim.audit_permanently_lost(), 0);
    }

    #[test]
    fn wipe_clears_a_node_and_peers_refill_it() {
        let mut c = cfg(3, 3, 2, 2);
        c.antientropy.period_us = 20_000;
        c.durability.flush_every_ops = 1;
        let mut sim = Sim::new(DvvMech, c, 4, true, small_workload(4, 20), 41).unwrap();
        sim.schedule_wipe(60_000, 1);
        sim.start();
        sim.run(u64::MAX);
        sim.settle();
        assert_eq!(sim.audit_acked_lost(), 0, "{}", sim.metrics.summary());
        // anti-entropy + settle refilled the wiped node
        for key in sim.nodes[0].store.keys() {
            assert_eq!(
                sim.nodes[1].store.state(key),
                sim.nodes[0].store.state(key),
                "wiped node reconverged on key {key}"
            );
        }
    }

    #[test]
    fn volatile_restart_loses_everything_but_nothing_acked() {
        // durability model off (flush_every_ops = 0): a restart is total
        // loss at that node, like the in-memory threaded backends
        let mut c = cfg(3, 3, 2, 2);
        c.antientropy.period_us = 20_000;
        let mut sim = Sim::new(DvvMech, c, 4, true, small_workload(4, 20), 43).unwrap();
        sim.schedule_restart(60_000, 2);
        sim.start();
        sim.run(u64::MAX);
        sim.settle();
        assert_eq!(sim.audit_acked_lost(), 0, "{}", sim.metrics.summary());
        assert!(sim.writes_acked() > 0);
    }

    fn geo_cfg(zones: &[usize], n: usize, r: usize, w: usize) -> StoreConfig {
        let mut c = cfg(zones.len(), n, r, w);
        c.cluster.zones = zones.to_vec();
        c
    }

    #[test]
    fn geo_run_ships_cross_dc_and_loses_nothing_acked() {
        let mut c = geo_cfg(&[0, 0, 0, 1, 1, 1], 3, 2, 2);
        c.antientropy.period_us = 20_000;
        c.geo.ship_interval_us = 10_000;
        let mut sim = Sim::new(DvvMech, c, 6, true, small_workload(6, 30), 51).unwrap();
        sim.start();
        sim.run(u64::MAX);
        assert!(sim.metrics.ship_batches > 0, "{}", sim.metrics.summary());
        assert_eq!(sim.metrics.failed_ops, 0, "{}", sim.metrics.summary());
        sim.settle();
        assert_eq!(sim.audit_acked_lost(), 0, "{}", sim.metrics.summary());
    }

    #[test]
    fn hlc_stays_monotone_under_backward_clock_skew() {
        let mut c = geo_cfg(&[0, 1], 2, 1, 1);
        c.geo.ship_interval_us = 5_000;
        let mut sim = Sim::new(DvvMech, c, 2, true, Box::new(NoDriver), 53).unwrap();
        let mut prev = [sim.node_hlc(0), sim.node_hlc(1)];
        for i in 0..30u64 {
            if i == 10 {
                // physical clock on node 0 steps back a full second
                let now = sim.now();
                sim.schedule_clock_skew(now + 1, 0, -1_000_000);
            }
            sim.sync_put(0, i % 3, 4, &Default::default(), &[]).unwrap();
            for n in 0..2 {
                let t = sim.node_hlc(n);
                assert!(t >= prev[n], "node {n} HLC regressed: {t} < {}", prev[n]);
                prev[n] = t;
            }
        }
        assert!(sim.nodes[0].skew_us < 0, "the skew event landed");
        // bounded drift: l never runs ahead of the largest physical
        // input, which unskewed nodes cap at simulated time
        assert!(sim.node_hlc(0).l <= sim.now());
        assert!(sim.node_hlc(1).l <= sim.now());
    }

    #[test]
    fn geo_put_parks_remote_homes_for_the_shipper() {
        let mut c = geo_cfg(&[0, 0, 1, 1], 4, 1, 1);
        c.geo.ship_interval_us = 0; // shipper off: parked keys stay parked
        let mut sim = Sim::new(DvvMech, c, 1, true, Box::new(NoDriver), 57).unwrap();
        sim.sync_put(0, 9, 4, &Default::default(), &[]).unwrap();
        // N = 4 over two DCs: two remote homes exist, so the write parks
        // key 9 at its coordinator instead of blocking on cross-DC acks
        assert_eq!(sim.ship_lag(), 1, "one key parked for shipment");
        sim.settle();
        assert_eq!(sim.audit_acked_lost(), 0);
    }

    #[test]
    fn metadata_sampled_at_finish() {
        let mut sim = Sim::new(
            DvvMech,
            cfg(3, 3, 2, 2),
            4,
            true,
            small_workload(4, 10),
            17,
        )
        .unwrap();
        sim.start();
        sim.run(u64::MAX);
        assert!(sim.metrics.metadata_bytes > 0);
    }
}
