//! Failure-injection schedules: declarative crash/partition/degradation
//! scripts that tests and benches can apply to a [`super::Sim`] — and,
//! through [`crate::server::fabric::Fabric::advance`], to the threaded
//! [`crate::server::LocalCluster`]. One [`FaultPlan`] drives both worlds
//! so a scenario validated in the deterministic simulator can be replayed
//! against the production-shaped code under real concurrency.

use crate::cluster::NodeId;
use crate::kernel::Mechanism;
use crate::testkit::Rng;

/// One failure-injection action.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Crash a node at a time.
    Crash {
        /// When (simulated µs).
        at: u64,
        /// Which node.
        node: NodeId,
    },
    /// Recover a node at a time.
    Recover {
        /// When (simulated µs).
        at: u64,
        /// Which node.
        node: NodeId,
    },
    /// Split the cluster into two groups.
    Partition {
        /// When (simulated µs).
        at: u64,
        /// Left group.
        left: Vec<NodeId>,
        /// Right group.
        right: Vec<NodeId>,
    },
    /// Heal all partitions.
    Heal {
        /// When (simulated µs).
        at: u64,
    },
    /// Degrade the network from a time on: probabilistic message drops
    /// plus a fixed extra one-way delay on every inter-replica message.
    /// `(0, 0)` restores the configured baseline. Drop probability is
    /// kept in parts-per-million so the enum stays `Eq`.
    Degrade {
        /// When (simulated µs).
        at: u64,
        /// Drop probability in parts-per-million (1_000_000 = always).
        drop_ppm: u32,
        /// Extra one-way delay per message (µs).
        extra_delay_us: u64,
    },
    /// Admit a new node at a time. Node ids are dense in both worlds, so
    /// the newcomer's id is deterministic: the next unallocated slot.
    Join {
        /// When (simulated µs).
        at: u64,
    },
    /// Retire a member at a time: its ranges re-route and its keys hand
    /// off to the new homes. The id is never reused.
    Decommission {
        /// When (simulated µs).
        at: u64,
        /// Which node.
        node: NodeId,
    },
    /// Kill and immediately restart a node's **process**: everything its
    /// storage has not durably persisted is lost; the persisted prefix
    /// recovers. On a durable backend (or a DES node with a durability
    /// model) that is the unsynced WAL tail; on a volatile node it is
    /// everything. Hinted handoff + anti-entropy close the gap.
    Restart {
        /// When (simulated µs).
        at: u64,
        /// Which node.
        node: NodeId,
    },
    /// Destroy a node's state entirely — disk included. The node stays a
    /// member and rejoins empty; its peers refill it.
    Wipe {
        /// When (simulated µs).
        at: u64,
        /// Which node.
        node: NodeId,
    },
    /// Step one node's **physical clock** by a signed offset — the
    /// GentleRain+ anomaly driver. A negative `delta_us` makes the
    /// node's injected physical time run behind, which is exactly the
    /// case the hybrid logical clock ([`crate::clocks::Hlc`]) must stay
    /// monotone through. Cumulative: two skews add.
    ClockSkew {
        /// When (simulated µs).
        at: u64,
        /// Which node.
        node: NodeId,
        /// Signed offset added to the node's physical clock (µs).
        delta_us: i64,
    },
}

impl Fault {
    /// When the fault fires (simulated µs).
    pub fn at(&self) -> u64 {
        match self {
            Fault::Crash { at, .. }
            | Fault::Recover { at, .. }
            | Fault::Partition { at, .. }
            | Fault::Heal { at }
            | Fault::Degrade { at, .. }
            | Fault::Join { at }
            | Fault::Decommission { at, .. }
            | Fault::Restart { at, .. }
            | Fault::Wipe { at, .. }
            | Fault::ClockSkew { at, .. } => *at,
        }
    }
}

/// Convert a drop probability to the parts-per-million encoding used by
/// [`Fault::Degrade`].
pub fn drop_ppm(prob: f64) -> u32 {
    assert!((0.0..=1.0).contains(&prob), "drop probability {prob} not in [0, 1]");
    (prob * 1_000_000.0).round() as u32
}

/// A reusable fault schedule.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Ordered faults (order does not matter; the DES sorts by time).
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// Empty plan.
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Add a crash+recover window.
    pub fn crash_window(mut self, node: NodeId, from: u64, to: u64) -> Self {
        assert!(from < to);
        self.faults.push(Fault::Crash { at: from, node });
        self.faults.push(Fault::Recover { at: to, node });
        self
    }

    /// Add a partition window splitting the node set in two.
    pub fn partition_window(
        mut self,
        left: Vec<NodeId>,
        right: Vec<NodeId>,
        from: u64,
        to: u64,
    ) -> Self {
        assert!(from < to);
        self.faults.push(Fault::Partition { at: from, left, right });
        self.faults.push(Fault::Heal { at: to });
        self
    }

    /// Add a degradation window: `drop_prob` message loss plus
    /// `extra_delay_us` per message between `from` and `to`, after which
    /// the baseline is restored.
    pub fn degrade_window(
        mut self,
        drop_prob: f64,
        extra_delay_us: u64,
        from: u64,
        to: u64,
    ) -> Self {
        assert!(from < to);
        self.faults.push(Fault::Degrade {
            at: from,
            drop_ppm: drop_ppm(drop_prob),
            extra_delay_us,
        });
        self.faults.push(Fault::Degrade { at: to, drop_ppm: 0, extra_delay_us: 0 });
        self
    }

    /// Random symmetric partition windows: `windows` random two-group
    /// splits of the node set within `[0, horizon_us)`. Each window is
    /// placed in its own disjoint `horizon_us / windows` time slot — a
    /// [`Fault::Heal`] heals *all* partitions, so overlapping windows
    /// would cut each other short of their advertised duration. Window
    /// length is `dur_us`, capped below the slot length.
    pub fn random_partitions(
        mut self,
        nodes: usize,
        windows: usize,
        dur_us: u64,
        horizon_us: u64,
        rng: &mut Rng,
    ) -> Self {
        assert!(nodes >= 2, "a partition needs at least two nodes");
        if windows == 0 {
            return self;
        }
        // every window needs a >= 2µs slot strictly inside the horizon
        assert!(
            horizon_us >= 2 * windows as u64,
            "horizon {horizon_us}µs too short for {windows} partition windows"
        );
        let slot = horizon_us / windows as u64;
        let dur = dur_us.clamp(1, slot - 1);
        for w in 0..windows as u64 {
            let base = w * slot;
            let start = base + rng.below(slot - dur);
            let mut ids: Vec<NodeId> = (0..nodes).collect();
            rng.shuffle(&mut ids);
            let cut = rng.range(1, nodes - 1);
            let right = ids.split_off(cut);
            self = self.partition_window(ids, right, start, start + dur);
        }
        self
    }

    /// A full random chaos schedule — crash windows, partition windows,
    /// and one degradation window — with every fault healed by
    /// `horizon_us`. This is the generator the fabric chaos property test
    /// replays across seeds (`rust/tests/fabric_chaos.rs`).
    pub fn random_chaos(nodes: usize, horizon_us: u64, rng: &mut Rng) -> FaultPlan {
        let dur = (horizon_us / 4).max(1);
        let latest_start = horizon_us.saturating_sub(dur).max(1);
        let mut plan = FaultPlan::new().random_crashes(nodes, 1, dur, latest_start, rng);
        if nodes >= 2 {
            plan = plan.random_partitions(nodes, 2, dur, latest_start, rng);
        }
        let drop_prob = 0.05 + rng.f64() * 0.20;
        let start = rng.below(latest_start);
        plan.degrade_window(drop_prob, rng.below(500), start, start + dur)
    }

    /// Admit a new node at `at` (ids are dense: the newcomer gets the
    /// next unallocated slot in whichever world replays the plan).
    pub fn join_at(mut self, at: u64) -> Self {
        self.faults.push(Fault::Join { at });
        self
    }

    /// Retire `node` at `at`, handing its key ranges to their new homes.
    pub fn decommission_at(mut self, at: u64, node: NodeId) -> Self {
        self.faults.push(Fault::Decommission { at, node });
        self
    }

    /// Crash-restart `node`'s process at `at` (unpersisted state lost).
    pub fn restart_at(mut self, at: u64, node: NodeId) -> Self {
        self.faults.push(Fault::Restart { at, node });
        self
    }

    /// Wipe `node`'s state (disk included) at `at`.
    pub fn wipe_at(mut self, at: u64, node: NodeId) -> Self {
        self.faults.push(Fault::Wipe { at, node });
        self
    }

    /// Step `node`'s physical clock by `delta_us` at `at` (negative =
    /// backward jump — the HLC anomaly case). Cumulative across calls.
    pub fn clock_skew_at(mut self, at: u64, node: NodeId, delta_us: i64) -> Self {
        self.faults.push(Fault::ClockSkew { at, node, delta_us });
        self
    }

    /// Partition one whole datacenter away from the rest between `from`
    /// and `to`: `zones[i]` is node `i`'s zone, and every node of `dc`
    /// lands on one side of a symmetric partition with everyone else on
    /// the other. This is the geo marquee scenario as a one-liner —
    /// both halves keep serving on their per-DC sloppy quorums, then the
    /// heal lets the cross-DC shipper and anti-entropy converge them.
    pub fn partition_dc_at(self, zones: &[usize], dc: usize, from: u64, to: u64) -> Self {
        let inside: Vec<NodeId> =
            (0..zones.len()).filter(|&n| zones[n] == dc).collect();
        let outside: Vec<NodeId> =
            (0..zones.len()).filter(|&n| zones[n] != dc).collect();
        assert!(
            !inside.is_empty() && !outside.is_empty(),
            "DC {dc} must split the node set in two (zones {zones:?})"
        );
        self.partition_window(inside, outside, from, to)
    }

    /// Random geo chaos: one whole-DC partition window (random DC),
    /// one backward clock skew on a random node, and a degradation
    /// window — all healed by `horizon_us`. The geo analogue of
    /// [`random_chaos`](FaultPlan::random_chaos); the geo chaos property
    /// test replays it across seeds under `GEO_ITERS`.
    pub fn random_geo_chaos(zones: &[usize], horizon_us: u64, rng: &mut Rng) -> FaultPlan {
        let mut distinct: Vec<usize> = zones.to_vec();
        distinct.sort_unstable();
        distinct.dedup();
        assert!(distinct.len() >= 2, "geo chaos needs at least two DCs");
        let dur = (horizon_us / 4).max(1);
        let latest_start = horizon_us.saturating_sub(dur).max(1);
        let dc = distinct[rng.below(distinct.len() as u64) as usize];
        let start = rng.below(latest_start);
        let mut plan =
            FaultPlan::new().partition_dc_at(zones, dc, start, start + dur);
        // one backward jump mid-horizon: HLC monotonicity under anomaly
        let node = rng.below(zones.len() as u64) as usize;
        let jump = -((1 + rng.below(500_000)) as i64);
        plan = plan.clock_skew_at(rng.below(latest_start), node, jump);
        let drop_prob = 0.02 + rng.f64() * 0.10;
        let dstart = rng.below(latest_start);
        plan.degrade_window(drop_prob, rng.below(300), dstart, dstart + dur)
    }

    /// Add **one** state-loss event — a wipe or a crash-restart, on a
    /// random node, somewhere in the middle half of `[0, horizon_us)`.
    ///
    /// Exactly one per plan on purpose: with `W` write-quorum copies, a
    /// single node's loss is always survivable (the other ackers hold
    /// the data until anti-entropy re-propagates it). Two loss events
    /// with no guaranteed anti-entropy round between them could destroy
    /// every copy of an acknowledged write, which would be a scenario
    /// bug rather than a store bug — the durability chaos test
    /// (`rust/tests/durable_chaos.rs`) wants the strongest invariant the
    /// scenario actually guarantees.
    pub fn random_loss_event(mut self, nodes: usize, horizon_us: u64, rng: &mut Rng) -> Self {
        let at = horizon_us / 4 + rng.below((horizon_us / 2).max(1));
        let node = rng.below(nodes as u64) as usize;
        self.faults.push(if rng.chance(0.5) {
            Fault::Wipe { at, node }
        } else {
            Fault::Restart { at, node }
        });
        self
    }

    /// Random elastic churn: `cycles` join/decommission pairs inside
    /// `[0, horizon_us)`, each in its own disjoint time slot with the
    /// join strictly before the decommission. Victims are distinct nodes
    /// drawn from the `base_nodes` initial members (joined nodes get
    /// dense ids `base_nodes..`, identical in every world), so member
    /// count never drops below `base_nodes - 1` mid-cycle and ends at
    /// `base_nodes` exactly.
    pub fn random_churn(
        mut self,
        base_nodes: usize,
        cycles: usize,
        horizon_us: u64,
        rng: &mut Rng,
    ) -> Self {
        if cycles == 0 {
            return self;
        }
        assert!(
            cycles < base_nodes,
            "need base_nodes > cycles so distinct victims leave a quorum standing"
        );
        assert!(
            horizon_us >= 4 * cycles as u64,
            "horizon {horizon_us}µs too short for {cycles} churn cycles"
        );
        let mut victims: Vec<NodeId> = (0..base_nodes).collect();
        rng.shuffle(&mut victims);
        let slot = horizon_us / cycles as u64;
        for (c, &victim) in victims.iter().take(cycles).enumerate() {
            let base = c as u64 * slot;
            let half = slot / 2;
            let join_at = base + rng.below(half.max(1));
            let decom_at = base + half + rng.below(half.max(1));
            self.faults.push(Fault::Join { at: join_at });
            self.faults.push(Fault::Decommission { at: decom_at, node: victim });
        }
        self
    }

    /// Random crash windows: each node gets `windows` crash periods of
    /// `dur_us` within `[0, horizon_us)`.
    pub fn random_crashes(
        mut self,
        nodes: usize,
        windows: usize,
        dur_us: u64,
        horizon_us: u64,
        rng: &mut Rng,
    ) -> Self {
        for node in 0..nodes {
            for _ in 0..windows {
                let start = rng.below(horizon_us.saturating_sub(dur_us).max(1));
                self.faults.push(Fault::Crash { at: start, node });
                self.faults.push(Fault::Recover { at: start + dur_us, node });
            }
        }
        self
    }

    /// Apply the plan to a simulator (before `run`).
    pub fn apply<M: Mechanism>(&self, sim: &mut super::Sim<M>) {
        for f in &self.faults {
            match f {
                Fault::Crash { at, node } => sim.schedule_crash(*at, *node),
                Fault::Recover { at, node } => sim.schedule_recover(*at, *node),
                Fault::Partition { at, left, right } => {
                    sim.schedule_partition(*at, left.clone(), right.clone())
                }
                Fault::Heal { at } => sim.schedule_heal(*at),
                Fault::Degrade { at, drop_ppm, extra_delay_us } => {
                    sim.schedule_degrade(*at, *drop_ppm, *extra_delay_us)
                }
                Fault::Join { at } => sim.schedule_join(*at),
                Fault::Decommission { at, node } => sim.schedule_decommission(*at, *node),
                Fault::Restart { at, node } => sim.schedule_restart(*at, *node),
                Fault::Wipe { at, node } => sim.schedule_wipe(*at, *node),
                Fault::ClockSkew { at, node, delta_us } => {
                    sim.schedule_clock_skew(*at, *node, *delta_us)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_accumulate() {
        let plan = FaultPlan::new()
            .crash_window(0, 100, 200)
            .partition_window(vec![0], vec![1], 300, 400);
        assert_eq!(plan.faults.len(), 4);
        assert!(matches!(plan.faults[0], Fault::Crash { at: 100, node: 0 }));
        assert!(matches!(plan.faults[3], Fault::Heal { at: 400 }));
    }

    #[test]
    fn random_crashes_bounded() {
        let mut rng = Rng::new(5);
        let plan = FaultPlan::new().random_crashes(3, 2, 50, 1000, &mut rng);
        assert_eq!(plan.faults.len(), 12);
        for f in &plan.faults {
            match f {
                Fault::Crash { at, .. } => assert!(*at < 1000),
                Fault::Recover { at, .. } => assert!(*at <= 1050),
                _ => panic!("unexpected fault kind"),
            }
        }
    }

    #[test]
    #[should_panic]
    fn crash_window_validates_order() {
        let _ = FaultPlan::new().crash_window(0, 200, 100);
    }

    #[test]
    fn degrade_window_restores_baseline() {
        let plan = FaultPlan::new().degrade_window(0.25, 300, 100, 900);
        assert_eq!(plan.faults.len(), 2);
        assert_eq!(
            plan.faults[0],
            Fault::Degrade { at: 100, drop_ppm: 250_000, extra_delay_us: 300 }
        );
        assert_eq!(
            plan.faults[1],
            Fault::Degrade { at: 900, drop_ppm: 0, extra_delay_us: 0 }
        );
    }

    #[test]
    fn fault_at_reports_fire_time() {
        let plan = FaultPlan::new()
            .crash_window(1, 10, 20)
            .partition_window(vec![0], vec![1], 30, 40)
            .degrade_window(0.1, 0, 50, 60);
        let ats: Vec<u64> = plan.faults.iter().map(Fault::at).collect();
        assert_eq!(ats, vec![10, 20, 30, 40, 50, 60]);
    }

    #[test]
    fn random_partitions_split_every_node_once() {
        let mut rng = Rng::new(3);
        let plan = FaultPlan::new().random_partitions(5, 2, 100, 1000, &mut rng);
        assert_eq!(plan.faults.len(), 4);
        for f in &plan.faults {
            if let Fault::Partition { left, right, .. } = f {
                assert!(!left.is_empty() && !right.is_empty());
                let mut all: Vec<NodeId> = left.iter().chain(right).copied().collect();
                all.sort_unstable();
                assert_eq!(all, vec![0, 1, 2, 3, 4], "groups partition the node set");
            }
        }
    }

    #[test]
    fn random_chaos_heals_by_horizon() {
        for seed in [1, 2, 3] {
            let mut rng = Rng::new(seed);
            let plan = FaultPlan::random_chaos(5, 400_000, &mut rng);
            assert!(!plan.faults.is_empty());
            for f in &plan.faults {
                assert!(f.at() <= 400_000, "fault past horizon: {f:?}");
            }
            // every crash has a matching later recovery
            for f in &plan.faults {
                if let Fault::Crash { at, node } = f {
                    assert!(plan.faults.iter().any(
                        |g| matches!(g, Fault::Recover { at: r, node: n } if n == node && r > at)
                    ));
                }
            }
            // the last degrade restores the baseline
            let last_degrade = plan
                .faults
                .iter()
                .filter(|f| matches!(f, Fault::Degrade { .. }))
                .max_by_key(|f| f.at())
                .unwrap();
            assert!(matches!(
                last_degrade,
                Fault::Degrade { drop_ppm: 0, extra_delay_us: 0, .. }
            ));
        }
    }

    #[test]
    #[should_panic]
    fn drop_ppm_rejects_out_of_range() {
        let _ = drop_ppm(1.5);
    }

    #[test]
    fn churn_builders_record_fire_times() {
        let plan = FaultPlan::new().join_at(50).decommission_at(90, 2);
        assert_eq!(plan.faults, vec![
            Fault::Join { at: 50 },
            Fault::Decommission { at: 90, node: 2 },
        ]);
        assert_eq!(plan.faults.iter().map(Fault::at).collect::<Vec<_>>(), vec![50, 90]);
    }

    #[test]
    fn random_churn_pairs_joins_before_distinct_decommissions() {
        let mut rng = Rng::new(11);
        let plan = FaultPlan::new().random_churn(5, 3, 300_000, &mut rng);
        assert_eq!(plan.faults.len(), 6);
        let mut victims = Vec::new();
        for pair in plan.faults.chunks(2) {
            let (Fault::Join { at: j }, Fault::Decommission { at: d, node }) =
                (&pair[0], &pair[1])
            else {
                panic!("unexpected fault kinds: {pair:?}");
            };
            assert!(j < d, "join {j} precedes decommission {d}");
            assert!(*d < 300_000);
            assert!(*node < 5, "victims come from the base nodes");
            victims.push(*node);
        }
        victims.sort_unstable();
        victims.dedup();
        assert_eq!(victims.len(), 3, "victims are distinct");
    }

    #[test]
    fn loss_builders_record_fire_times() {
        let plan = FaultPlan::new().restart_at(70, 1).wipe_at(120, 2);
        assert_eq!(plan.faults, vec![
            Fault::Restart { at: 70, node: 1 },
            Fault::Wipe { at: 120, node: 2 },
        ]);
        assert_eq!(plan.faults.iter().map(Fault::at).collect::<Vec<_>>(), vec![70, 120]);
    }

    #[test]
    fn random_loss_event_is_single_and_bounded() {
        for seed in [1, 2, 3, 4] {
            let mut rng = Rng::new(seed);
            let plan = FaultPlan::new().random_loss_event(5, 400_000, &mut rng);
            assert_eq!(plan.faults.len(), 1, "exactly one loss event");
            match &plan.faults[0] {
                Fault::Wipe { at, node } | Fault::Restart { at, node } => {
                    assert!((100_000..300_000).contains(at), "mid-horizon: {at}");
                    assert!(*node < 5);
                }
                other => panic!("unexpected fault {other:?}"),
            }
        }
    }

    #[test]
    #[should_panic]
    fn random_churn_requires_enough_base_nodes() {
        let mut rng = Rng::new(1);
        let _ = FaultPlan::new().random_churn(3, 3, 100_000, &mut rng);
    }

    #[test]
    fn partition_dc_splits_along_zones() {
        let zones = [0, 0, 0, 1, 1, 1];
        let plan = FaultPlan::new().partition_dc_at(&zones, 1, 100, 500);
        assert_eq!(plan.faults.len(), 2);
        let Fault::Partition { at, left, right } = &plan.faults[0] else {
            panic!("expected a partition, got {:?}", plan.faults[0]);
        };
        assert_eq!(*at, 100);
        assert_eq!(left, &vec![3, 4, 5], "DC 1 on one side");
        assert_eq!(right, &vec![0, 1, 2], "everyone else on the other");
        assert!(matches!(plan.faults[1], Fault::Heal { at: 500 }));
    }

    #[test]
    #[should_panic]
    fn partition_dc_rejects_a_dc_holding_every_node() {
        let _ = FaultPlan::new().partition_dc_at(&[2, 2, 2], 2, 0, 10);
    }

    #[test]
    fn clock_skew_builder_records_signed_offsets() {
        let plan = FaultPlan::new().clock_skew_at(40, 2, -250_000).clock_skew_at(90, 2, 10);
        assert_eq!(plan.faults, vec![
            Fault::ClockSkew { at: 40, node: 2, delta_us: -250_000 },
            Fault::ClockSkew { at: 90, node: 2, delta_us: 10 },
        ]);
        assert_eq!(plan.faults.iter().map(Fault::at).collect::<Vec<_>>(), vec![40, 90]);
    }

    #[test]
    fn random_geo_chaos_heals_and_skews_within_horizon() {
        let zones = [0, 0, 1, 1, 2, 2];
        for seed in [1, 2, 3, 4] {
            let mut rng = Rng::new(seed);
            let plan = FaultPlan::random_geo_chaos(&zones, 400_000, &mut rng);
            assert!(plan.faults.iter().any(|f| matches!(f, Fault::Partition { .. })));
            assert!(plan.faults.iter().any(|f| matches!(f, Fault::Heal { .. })));
            let skews: Vec<&Fault> = plan
                .faults
                .iter()
                .filter(|f| matches!(f, Fault::ClockSkew { .. }))
                .collect();
            assert_eq!(skews.len(), 1);
            let Fault::ClockSkew { node, delta_us, .. } = skews[0] else { unreachable!() };
            assert!(*node < zones.len());
            assert!(*delta_us < 0, "the geo anomaly is a backward jump");
            for f in &plan.faults {
                assert!(f.at() <= 400_000, "fault past horizon: {f:?}");
            }
            // the DC partition groups cover the node set exactly
            if let Some(Fault::Partition { left, right, .. }) =
                plan.faults.iter().find(|f| matches!(f, Fault::Partition { .. }))
            {
                let mut all: Vec<NodeId> = left.iter().chain(right).copied().collect();
                all.sort_unstable();
                assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
                // each side is zone-pure on the partitioned DC's side
                let dcs: std::collections::HashSet<usize> =
                    left.iter().map(|&n| zones[n]).collect();
                assert_eq!(dcs.len(), 1, "the inside group is one whole DC");
            }
        }
    }
}
