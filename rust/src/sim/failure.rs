//! Failure-injection schedules: declarative crash/partition scripts that
//! tests and benches can apply to a [`super::Sim`].

use crate::cluster::NodeId;
use crate::kernel::Mechanism;
use crate::testkit::Rng;

/// One failure-injection action.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Crash a node at a time.
    Crash {
        /// When (simulated µs).
        at: u64,
        /// Which node.
        node: NodeId,
    },
    /// Recover a node at a time.
    Recover {
        /// When (simulated µs).
        at: u64,
        /// Which node.
        node: NodeId,
    },
    /// Split the cluster into two groups.
    Partition {
        /// When (simulated µs).
        at: u64,
        /// Left group.
        left: Vec<NodeId>,
        /// Right group.
        right: Vec<NodeId>,
    },
    /// Heal all partitions.
    Heal {
        /// When (simulated µs).
        at: u64,
    },
}

/// A reusable fault schedule.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Ordered faults (order does not matter; the DES sorts by time).
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// Empty plan.
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Add a crash+recover window.
    pub fn crash_window(mut self, node: NodeId, from: u64, to: u64) -> Self {
        assert!(from < to);
        self.faults.push(Fault::Crash { at: from, node });
        self.faults.push(Fault::Recover { at: to, node });
        self
    }

    /// Add a partition window splitting the node set in two.
    pub fn partition_window(
        mut self,
        left: Vec<NodeId>,
        right: Vec<NodeId>,
        from: u64,
        to: u64,
    ) -> Self {
        assert!(from < to);
        self.faults.push(Fault::Partition { at: from, left, right });
        self.faults.push(Fault::Heal { at: to });
        self
    }

    /// Random crash windows: each node gets `windows` crash periods of
    /// `dur_us` within `[0, horizon_us)`.
    pub fn random_crashes(
        mut self,
        nodes: usize,
        windows: usize,
        dur_us: u64,
        horizon_us: u64,
        rng: &mut Rng,
    ) -> Self {
        for node in 0..nodes {
            for _ in 0..windows {
                let start = rng.below(horizon_us.saturating_sub(dur_us).max(1));
                self.faults.push(Fault::Crash { at: start, node });
                self.faults.push(Fault::Recover { at: start + dur_us, node });
            }
        }
        self
    }

    /// Apply the plan to a simulator (before `run`).
    pub fn apply<M: Mechanism>(&self, sim: &mut super::Sim<M>) {
        for f in &self.faults {
            match f {
                Fault::Crash { at, node } => sim.schedule_crash(*at, *node),
                Fault::Recover { at, node } => sim.schedule_recover(*at, *node),
                Fault::Partition { at, left, right } => {
                    sim.schedule_partition(*at, left.clone(), right.clone())
                }
                Fault::Heal { at } => sim.schedule_heal(*at),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_accumulate() {
        let plan = FaultPlan::new()
            .crash_window(0, 100, 200)
            .partition_window(vec![0], vec![1], 300, 400);
        assert_eq!(plan.faults.len(), 4);
        assert!(matches!(plan.faults[0], Fault::Crash { at: 100, node: 0 }));
        assert!(matches!(plan.faults[3], Fault::Heal { at: 400 }));
    }

    #[test]
    fn random_crashes_bounded() {
        let mut rng = Rng::new(5);
        let plan = FaultPlan::new().random_crashes(3, 2, 50, 1000, &mut rng);
        assert_eq!(plan.faults.len(), 12);
        for f in &plan.faults {
            match f {
                Fault::Crash { at, .. } => assert!(*at < 1000),
                Fault::Recover { at, .. } => assert!(*at <= 1050),
                _ => panic!("unexpected fault kind"),
            }
        }
    }

    #[test]
    #[should_panic]
    fn crash_window_validates_order() {
        let _ = FaultPlan::new().crash_window(0, 200, 100);
    }
}
