//! Configuration system: typed config structs + a TOML-subset parser.
//!
//! The offline environment has no `serde`/`toml`, so this module parses the
//! subset the project needs: `[section]` / `[section.sub]` headers, `key =
//! value` pairs with integer, float, boolean, string, and flat-array
//! values, `#` comments, and blank lines.
//!
//! ```toml
//! [cluster]
//! nodes = 6
//! replication = 3
//! read_quorum = 2
//! write_quorum = 2
//! mechanism = "dvv"
//!
//! [net]
//! mean_latency_us = 500.0
//! drop_prob = 0.0
//! ```

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use crate::error::{Error, Result};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// 64-bit integer.
    Int(i64),
    /// Double float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Unquoted or quoted string.
    Str(String),
    /// Flat array of scalars.
    Array(Vec<Value>),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(v) => write!(f, "{v}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Bool(v) => write!(f, "{v}"),
            Value::Str(v) => write!(f, "{v:?}"),
            Value::Array(xs) => {
                write!(f, "[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Raw parsed config: dotted-path -> value.
#[derive(Debug, Clone, Default)]
pub struct Raw {
    entries: BTreeMap<String, Value>,
}

impl Raw {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Raw> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw_line) in text.lines().enumerate() {
            let line = strip_comment(raw_line).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err(lineno, "empty section name"));
                }
                section = name.to_string();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = k.trim();
                if key.is_empty() {
                    return Err(err(lineno, "empty key"));
                }
                let path = if section.is_empty() {
                    key.to_string()
                } else {
                    format!("{section}.{key}")
                };
                entries.insert(path, parse_value(v.trim(), lineno)?);
            } else {
                return Err(err(lineno, "expected `key = value` or `[section]`"));
            }
        }
        Ok(Raw { entries })
    }

    /// Load and parse a file.
    pub fn load(path: &Path) -> Result<Raw> {
        Raw::parse(&std::fs::read_to_string(path)?)
    }

    /// Look up a dotted path.
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    /// Integer at path, with default.
    pub fn int(&self, path: &str, default: i64) -> Result<i64> {
        match self.get(path) {
            None => Ok(default),
            Some(Value::Int(v)) => Ok(*v),
            Some(other) => Err(Error::Config(format!("{path}: expected int, got {other}"))),
        }
    }

    /// Float at path, with default (ints coerce).
    pub fn float(&self, path: &str, default: f64) -> Result<f64> {
        match self.get(path) {
            None => Ok(default),
            Some(Value::Float(v)) => Ok(*v),
            Some(Value::Int(v)) => Ok(*v as f64),
            Some(other) => Err(Error::Config(format!("{path}: expected float, got {other}"))),
        }
    }

    /// Bool at path, with default.
    pub fn bool(&self, path: &str, default: bool) -> Result<bool> {
        match self.get(path) {
            None => Ok(default),
            Some(Value::Bool(v)) => Ok(*v),
            Some(other) => Err(Error::Config(format!("{path}: expected bool, got {other}"))),
        }
    }

    /// String at path, with default.
    pub fn str(&self, path: &str, default: &str) -> Result<String> {
        match self.get(path) {
            None => Ok(default.to_string()),
            Some(Value::Str(v)) => Ok(v.clone()),
            Some(other) => Err(Error::Config(format!("{path}: expected string, got {other}"))),
        }
    }

    /// Integer array at path; a missing key yields an empty vec.
    pub fn int_array(&self, path: &str) -> Result<Vec<i64>> {
        match self.get(path) {
            None => Ok(Vec::new()),
            Some(Value::Array(xs)) => xs
                .iter()
                .map(|x| match x {
                    Value::Int(v) => Ok(*v),
                    other => Err(Error::Config(format!(
                        "{path}: expected int array element, got {other}"
                    ))),
                })
                .collect(),
            Some(other) => {
                Err(Error::Config(format!("{path}: expected array, got {other}")))
            }
        }
    }

    /// All dotted paths (for diagnostics).
    pub fn paths(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("line {}: {msg}", lineno + 1))
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if s.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?;
        let mut items = Vec::new();
        if !body.trim().is_empty() {
            for part in split_array(body) {
                items.push(parse_value(part.trim(), lineno)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        return Ok(Value::Str(body.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // bare word = string (ergonomic for mechanism names)
    Ok(Value::Str(s.to_string()))
}

fn split_array(body: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in body.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&body[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < body.len() {
        parts.push(&body[start..]);
    }
    parts
}

// ---------------------------------------------------------------------------
// Typed configs
// ---------------------------------------------------------------------------

/// Cluster topology + quorum configuration (§2 system model).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Total server nodes in the ring.
    pub nodes: usize,
    /// Replication degree N (replica nodes per key).
    pub replication: usize,
    /// Read quorum R.
    pub read_quorum: usize,
    /// Write quorum W.
    pub write_quorum: usize,
    /// Virtual nodes per server on the consistent-hash ring.
    pub vnodes: usize,
    /// Causality mechanism name (see `clocks::mechanism_names`).
    pub mechanism: String,
    /// Coordinator choice per PUT: `false` = first live node of the
    /// preference list (sticky); `true` = uniformly random live replica
    /// (Dynamo-style "any node coordinates" — the §3.3/Figure 4 setting
    /// where stateless-client inference goes wrong).
    pub random_coordinator: bool,
    /// Per-node DC assignment: `zones[i]` is node `i`'s zone. Empty =
    /// flat single-DC cluster (geo-replication off, the default); when
    /// set, its length must equal `nodes` and placement switches to the
    /// zone-spreading walk.
    pub zones: Vec<usize>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 6,
            replication: 3,
            read_quorum: 2,
            write_quorum: 2,
            vnodes: 64,
            mechanism: "dvv".to_string(),
            random_coordinator: false,
            zones: Vec::new(),
        }
    }
}

/// Geo-replication (cross-DC) parameters. Only consulted when
/// `cluster.zones` is set.
#[derive(Debug, Clone, PartialEq)]
pub struct GeoConfig {
    /// Cross-DC shipper cadence (µs of simulated time): each node drains
    /// its remote-DC buffer this often. 0 disables the shipper (cross-DC
    /// AE becomes the only repair path).
    pub ship_interval_us: u64,
    /// Probability that an anti-entropy round picks a **remote-DC** peer
    /// instead of a same-zone one — the low-frequency cross-DC repair
    /// backstop.
    pub cross_dc_ae_prob: f64,
}

impl Default for GeoConfig {
    fn default() -> Self {
        GeoConfig { ship_interval_us: 20_000, cross_dc_ae_prob: 0.1 }
    }
}

/// Simulated-network parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct NetConfig {
    /// Mean one-way message latency (µs, exponential distribution).
    pub mean_latency_us: f64,
    /// Independent message-drop probability.
    pub drop_prob: f64,
    /// Std-dev of per-client wall-clock skew (µs) for the LWW baseline.
    pub clock_skew_us: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { mean_latency_us: 500.0, drop_prob: 0.0, clock_skew_us: 0.0 }
    }
}

/// Anti-entropy parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct AntiEntropyConfig {
    /// Exchange period (µs of simulated time); 0 disables anti-entropy.
    pub period_us: u64,
    /// Use the XLA bulk-dominance artifact above this batch size.
    pub xla_batch_threshold: usize,
    /// Detect divergence via the incremental hash trees
    /// ([`crate::antientropy::merkle`]) instead of a full-state scan —
    /// the default; `false` keeps the exact scan path (the equivalence
    /// tests run both).
    pub merkle: bool,
}

impl Default for AntiEntropyConfig {
    fn default() -> Self {
        AntiEntropyConfig { period_us: 0, xla_batch_threshold: usize::MAX, merkle: true }
    }
}

/// Durability model for the discrete-event simulator: the DES analogue
/// of the threaded cluster's write-ahead log + fsync policy
/// ([`crate::store::wal`]). Each simulated node keeps a logical WAL of
/// its mutations with a **persisted prefix**; a `Fault::Restart` rolls
/// the node back to that prefix (crash loss), a `Fault::Wipe` clears it
/// entirely.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DurabilityConfig {
    /// Advance the persisted prefix every this many mutations — the DES
    /// mirror of `FsyncPolicy::EveryN` (1 ≙ `Always`). `0` disables the
    /// model: nodes are volatile and a restart loses everything, exactly
    /// like the in-memory backends in the threaded world.
    pub flush_every_ops: u64,
}

/// Top-level store configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StoreConfig {
    /// Cluster/quorum section.
    pub cluster: ClusterConfig,
    /// Network simulation section.
    pub net: NetConfig,
    /// Anti-entropy section.
    pub antientropy: AntiEntropyConfig,
    /// DES durability-model section.
    pub durability: DurabilityConfig,
    /// Geo-replication section.
    pub geo: GeoConfig,
}

impl StoreConfig {
    /// Build from parsed raw config (missing keys take defaults).
    pub fn from_raw(raw: &Raw) -> Result<StoreConfig> {
        let d = StoreConfig::default();
        let cfg = StoreConfig {
            cluster: ClusterConfig {
                nodes: raw.int("cluster.nodes", d.cluster.nodes as i64)? as usize,
                replication: raw.int("cluster.replication", d.cluster.replication as i64)?
                    as usize,
                read_quorum: raw.int("cluster.read_quorum", d.cluster.read_quorum as i64)?
                    as usize,
                write_quorum: raw.int("cluster.write_quorum", d.cluster.write_quorum as i64)?
                    as usize,
                vnodes: raw.int("cluster.vnodes", d.cluster.vnodes as i64)? as usize,
                mechanism: raw.str("cluster.mechanism", &d.cluster.mechanism)?,
                random_coordinator: raw
                    .bool("cluster.random_coordinator", d.cluster.random_coordinator)?,
                zones: raw
                    .int_array("cluster.zones")?
                    .into_iter()
                    .map(|z| {
                        usize::try_from(z).map_err(|_| {
                            Error::Config("cluster.zones entries must be >= 0".into())
                        })
                    })
                    .collect::<Result<Vec<usize>>>()?,
            },
            net: NetConfig {
                mean_latency_us: raw.float("net.mean_latency_us", d.net.mean_latency_us)?,
                drop_prob: raw.float("net.drop_prob", d.net.drop_prob)?,
                clock_skew_us: raw.float("net.clock_skew_us", d.net.clock_skew_us)?,
            },
            antientropy: AntiEntropyConfig {
                period_us: raw.int("antientropy.period_us", d.antientropy.period_us as i64)?
                    as u64,
                xla_batch_threshold: raw.int(
                    "antientropy.xla_batch_threshold",
                    d.antientropy.xla_batch_threshold as i64,
                )? as usize,
                merkle: raw.bool("antientropy.merkle", d.antientropy.merkle)?,
            },
            durability: DurabilityConfig {
                // checked conversion: a negative value must be rejected,
                // not wrapped into a cadence that never flushes
                flush_every_ops: u64::try_from(raw.int(
                    "durability.flush_every_ops",
                    d.durability.flush_every_ops as i64,
                )?)
                .map_err(|_| {
                    Error::Config("durability.flush_every_ops must be >= 0".into())
                })?,
            },
            geo: GeoConfig {
                ship_interval_us: raw
                    .int("geo.ship_interval_us", d.geo.ship_interval_us as i64)?
                    as u64,
                cross_dc_ae_prob: raw
                    .float("geo.cross_dc_ae_prob", d.geo.cross_dc_ae_prob)?,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> Result<StoreConfig> {
        StoreConfig::from_raw(&Raw::load(path)?)
    }

    /// Sanity-check quorum arithmetic.
    pub fn validate(&self) -> Result<()> {
        let c = &self.cluster;
        if c.replication == 0 || c.replication > c.nodes {
            return Err(Error::Config(format!(
                "replication {} must be in 1..=nodes ({})",
                c.replication, c.nodes
            )));
        }
        if c.read_quorum == 0 || c.read_quorum > c.replication {
            return Err(Error::Config("read_quorum must be in 1..=replication".into()));
        }
        if c.write_quorum == 0 || c.write_quorum > c.replication {
            return Err(Error::Config("write_quorum must be in 1..=replication".into()));
        }
        if !(0.0..=1.0).contains(&self.net.drop_prob) {
            return Err(Error::Config("drop_prob must be within [0, 1]".into()));
        }
        if !c.zones.is_empty() && c.zones.len() != c.nodes {
            return Err(Error::Config(format!(
                "cluster.zones has {} entries for {} nodes",
                c.zones.len(),
                c.nodes
            )));
        }
        if !(0.0..=1.0).contains(&self.geo.cross_dc_ae_prob) {
            return Err(Error::Config("geo.cross_dc_ae_prob must be within [0, 1]".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# cluster layout
[cluster]
nodes = 6
replication = 3
read_quorum = 2       # R
write_quorum = 2
mechanism = "dvv"

[net]
mean_latency_us = 250.5
drop_prob = 0.01

[antientropy]
period_us = 100000
"#;

    #[test]
    fn negative_flush_cadence_is_rejected_not_wrapped() {
        let raw = Raw::parse("[durability]\nflush_every_ops = -1\n").unwrap();
        assert!(StoreConfig::from_raw(&raw).is_err());
        let raw = Raw::parse("[durability]\nflush_every_ops = 8\n").unwrap();
        assert_eq!(StoreConfig::from_raw(&raw).unwrap().durability.flush_every_ops, 8);
    }

    #[test]
    fn parses_sections_and_scalars() {
        let raw = Raw::parse(SAMPLE).unwrap();
        assert_eq!(raw.get("cluster.nodes"), Some(&Value::Int(6)));
        assert_eq!(raw.get("net.mean_latency_us"), Some(&Value::Float(250.5)));
        assert_eq!(raw.get("cluster.mechanism"), Some(&Value::Str("dvv".into())));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let raw = Raw::parse("# top\n\nx = 1 # end\n").unwrap();
        assert_eq!(raw.get("x"), Some(&Value::Int(1)));
    }

    #[test]
    fn hash_inside_string_preserved() {
        let raw = Raw::parse("k = \"a#b\"").unwrap();
        assert_eq!(raw.get("k"), Some(&Value::Str("a#b".into())));
    }

    #[test]
    fn arrays() {
        let raw = Raw::parse("xs = [1, 2, 3]\nys = [\"a\", \"b\"]\nempty = []").unwrap();
        assert_eq!(
            raw.get("xs"),
            Some(&Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)]))
        );
        assert_eq!(
            raw.get("ys"),
            Some(&Value::Array(vec![Value::Str("a".into()), Value::Str("b".into())]))
        );
        assert_eq!(raw.get("empty"), Some(&Value::Array(vec![])));
    }

    #[test]
    fn booleans_and_bare_words() {
        let raw = Raw::parse("a = true\nb = false\nmech = dvv").unwrap();
        assert_eq!(raw.get("a"), Some(&Value::Bool(true)));
        assert_eq!(raw.get("b"), Some(&Value::Bool(false)));
        assert_eq!(raw.get("mech"), Some(&Value::Str("dvv".into())));
    }

    #[test]
    fn bad_lines_error_with_lineno() {
        let e = Raw::parse("x = 1\njunk").unwrap_err();
        assert!(e.to_string().contains("line 2"), "{e}");
        let e = Raw::parse("[open").unwrap_err();
        assert!(e.to_string().contains("line 1"), "{e}");
    }

    #[test]
    fn typed_config_from_raw() {
        let cfg = StoreConfig::from_raw(&Raw::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.cluster.nodes, 6);
        assert_eq!(cfg.cluster.replication, 3);
        assert_eq!(cfg.net.mean_latency_us, 250.5);
        assert_eq!(cfg.antientropy.period_us, 100_000);
        assert_eq!(cfg.cluster.mechanism, "dvv");
    }

    #[test]
    fn defaults_when_missing() {
        let cfg = StoreConfig::from_raw(&Raw::parse("").unwrap()).unwrap();
        assert_eq!(cfg, StoreConfig::default());
    }

    #[test]
    fn geo_section_parses_and_validates() {
        let raw = Raw::parse(
            "[cluster]\nnodes = 4\nzones = [0, 0, 1, 1]\n[geo]\nship_interval_us = 5000\ncross_dc_ae_prob = 0.25\n",
        )
        .unwrap();
        let cfg = StoreConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.cluster.zones, vec![0, 0, 1, 1]);
        assert_eq!(cfg.geo.ship_interval_us, 5000);
        assert_eq!(cfg.geo.cross_dc_ae_prob, 0.25);
        // zones length must match nodes
        let raw = Raw::parse("[cluster]\nnodes = 4\nzones = [0, 1]\n").unwrap();
        assert!(StoreConfig::from_raw(&raw).is_err());
        // negative zone ids and bad probabilities are rejected
        let raw = Raw::parse("[cluster]\nnodes = 2\nzones = [0, -1]\n").unwrap();
        assert!(StoreConfig::from_raw(&raw).is_err());
        let raw = Raw::parse("[geo]\ncross_dc_ae_prob = 1.5\n").unwrap();
        assert!(StoreConfig::from_raw(&raw).is_err());
        // empty zones stays the flat default
        let cfg = StoreConfig::from_raw(&Raw::parse("").unwrap()).unwrap();
        assert!(cfg.cluster.zones.is_empty());
        assert_eq!(cfg.geo, GeoConfig::default());
    }

    #[test]
    fn int_array_accessor_coerces_and_rejects() {
        let raw = Raw::parse("xs = [3, 1, 2]\nbad = [1, \"a\"]\nscalar = 7\n").unwrap();
        assert_eq!(raw.int_array("xs").unwrap(), vec![3, 1, 2]);
        assert_eq!(raw.int_array("missing").unwrap(), Vec::<i64>::new());
        assert!(raw.int_array("bad").is_err());
        assert!(raw.int_array("scalar").is_err());
    }

    #[test]
    fn validation_rejects_bad_quorums() {
        let raw = Raw::parse("[cluster]\nnodes = 3\nreplication = 5").unwrap();
        assert!(StoreConfig::from_raw(&raw).is_err());
        let raw = Raw::parse("[cluster]\nread_quorum = 9").unwrap();
        assert!(StoreConfig::from_raw(&raw).is_err());
        let raw = Raw::parse("[net]\ndrop_prob = 1.5").unwrap();
        assert!(StoreConfig::from_raw(&raw).is_err());
    }
}
