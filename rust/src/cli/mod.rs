//! Tiny CLI argument parser (offline `clap` substitute).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! positional arguments, defaults, and generated `--help` text. Used by the
//! `dvv-store` binary and the examples.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Declarative description of one option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_switch: bool,
    required: bool,
    /// Allowed values (enum option); empty = any value accepted.
    choices: Vec<String>,
}

/// A command (or subcommand) parser.
#[derive(Debug, Clone)]
pub struct Command {
    name: String,
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>, // (name, help)
    subs: Vec<Command>,
}

/// Parsed argument values for a command invocation.
#[derive(Debug, Clone, Default)]
pub struct Matches {
    /// Resolved `--flag` values (after defaults).
    values: BTreeMap<String, String>,
    /// Switches that were present.
    switches: BTreeMap<String, bool>,
    /// Positional arguments in order.
    pub positionals: Vec<String>,
    /// Chosen subcommand, if any.
    pub subcommand: Option<(String, Box<Matches>)>,
}

impl Matches {
    /// String value of an option (default applied); None if absent.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Required string value (parser guarantees presence for required
    /// options / options with defaults).
    pub fn get_str(&self, name: &str) -> &str {
        self.get(name)
            .unwrap_or_else(|| panic!("option --{name} missing (declare a default)"))
    }

    /// Parse an option as `T`.
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<T> {
        let raw = self
            .get(name)
            .ok_or_else(|| Error::Config(format!("missing --{name}")))?;
        raw.parse()
            .map_err(|_| Error::Config(format!("--{name}: cannot parse {raw:?}")))
    }

    /// True when a boolean switch was given.
    pub fn has(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }
}

impl Command {
    /// New command with a help blurb.
    pub fn new(name: &str, about: &str) -> Command {
        Command {
            name: name.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            positionals: Vec::new(),
            subs: Vec::new(),
        }
    }

    /// Add `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_switch: false,
            required: false,
            choices: Vec::new(),
        });
        self
    }

    /// Add `--name <value>` restricted to a fixed set of values, with a
    /// default. Anything outside `choices` is rejected at parse time
    /// (listing the legal values), not deep inside the command.
    pub fn opt_choice(mut self, name: &str, default: &str, choices: &[&str], help: &str) -> Self {
        debug_assert!(choices.contains(&default), "default must be a legal choice");
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_switch: false,
            required: false,
            choices: choices.iter().map(|c| c.to_string()).collect(),
        });
        self
    }

    /// Add a required `--name <value>` (no default).
    pub fn opt_required(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_switch: false,
            required: true,
            choices: Vec::new(),
        });
        self
    }

    /// Add an optional `--name <value>` with no default.
    pub fn opt_optional(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_switch: false,
            required: false,
            choices: Vec::new(),
        });
        self
    }

    /// Add a boolean `--name` switch.
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_switch: true,
            required: false,
            choices: Vec::new(),
        });
        self
    }

    /// Add a positional argument (documentation only; collected in order).
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    /// Attach a subcommand.
    pub fn subcommand(mut self, sub: Command) -> Self {
        self.subs.push(sub);
        self
    }

    /// Render `--help`.
    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        if !self.subs.is_empty() {
            out.push_str(" <SUBCOMMAND>");
        }
        if !self.opts.is_empty() {
            out.push_str(" [OPTIONS]");
        }
        for (p, _) in &self.positionals {
            out.push_str(&format!(" <{p}>"));
        }
        out.push('\n');
        if !self.subs.is_empty() {
            out.push_str("\nSUBCOMMANDS:\n");
            for s in &self.subs {
                out.push_str(&format!("  {:<14} {}\n", s.name, s.about));
            }
        }
        if !self.opts.is_empty() {
            out.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let meta = if o.is_switch {
                    format!("--{}", o.name)
                } else if o.choices.is_empty() {
                    format!("--{} <v>", o.name)
                } else {
                    format!("--{} <{}>", o.name, o.choices.join("|"))
                };
                let dflt = match &o.default {
                    Some(d) => format!(" [default: {d}]"),
                    None if o.required => " [required]".to_string(),
                    None => String::new(),
                };
                out.push_str(&format!("  {:<22} {}{}\n", meta, o.help, dflt));
            }
        }
        out
    }

    /// Parse an argument list (without argv[0]).
    pub fn parse(&self, args: &[String]) -> Result<Matches> {
        let mut m = Matches::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                m.values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(Error::Config(self.help()));
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| Error::Config(format!("unknown option --{name}")))?;
                if spec.is_switch {
                    m.switches.insert(name, true);
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| Error::Config(format!("--{name} needs a value")))?
                        }
                    };
                    if !spec.choices.is_empty() && !spec.choices.contains(&value) {
                        return Err(Error::Config(format!(
                            "--{name}: {value:?} is not one of [{}]",
                            spec.choices.join(", ")
                        )));
                    }
                    m.values.insert(name, value);
                }
            } else if let Some(sub) = self.subs.iter().find(|s| s.name == *a) {
                let rest = sub.parse(&args[i + 1..])?;
                m.subcommand = Some((sub.name.clone(), Box::new(rest)));
                break;
            } else {
                m.positionals.push(a.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if o.required && !m.values.contains_key(&o.name) {
                return Err(Error::Config(format!("missing required --{}", o.name)));
            }
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("dvv-store", "test")
            .opt("nodes", "3", "node count")
            .switch("verbose", "chatty")
            .subcommand(
                Command::new("figures", "replay paper figures")
                    .opt("fig", "7", "figure number"),
            )
            .subcommand(Command::new("sim", "run simulation").opt_required("seed", "rng seed"))
    }

    #[test]
    fn defaults_apply() {
        let m = cmd().parse(&args(&[])).unwrap();
        assert_eq!(m.get_str("nodes"), "3");
        assert!(!m.has("verbose"));
    }

    #[test]
    fn values_and_switches() {
        let m = cmd().parse(&args(&["--nodes", "5", "--verbose"])).unwrap();
        assert_eq!(m.get_parsed::<usize>("nodes").unwrap(), 5);
        assert!(m.has("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let m = cmd().parse(&args(&["--nodes=9"])).unwrap();
        assert_eq!(m.get_str("nodes"), "9");
    }

    #[test]
    fn subcommand_parsing() {
        let m = cmd().parse(&args(&["figures", "--fig", "3"])).unwrap();
        let (name, sub) = m.subcommand.unwrap();
        assert_eq!(name, "figures");
        assert_eq!(sub.get_str("fig"), "3");
    }

    #[test]
    fn required_option_enforced() {
        let err = cmd().parse(&args(&["sim"])).unwrap_err();
        assert!(err.to_string().contains("seed"));
        let ok = cmd().parse(&args(&["sim", "--seed", "42"])).unwrap();
        assert_eq!(ok.subcommand.unwrap().1.get_str("seed"), "42");
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&args(&["--bogus", "1"])).is_err());
    }

    #[test]
    fn positionals_collected() {
        let c = Command::new("x", "t").positional("key", "the key");
        let m = c.parse(&args(&["mykey", "other"])).unwrap();
        assert_eq!(m.positionals, vec!["mykey", "other"]);
    }

    #[test]
    fn choice_option_validated_at_parse_time() {
        let c = Command::new("x", "t").opt_choice("mode", "reactor", &["reactor", "threads"], "serve mode");
        // default applies untouched
        assert_eq!(c.parse(&args(&[])).unwrap().get_str("mode"), "reactor");
        // both legal values, both syntaxes
        assert_eq!(c.parse(&args(&["--mode", "threads"])).unwrap().get_str("mode"), "threads");
        assert_eq!(c.parse(&args(&["--mode=reactor"])).unwrap().get_str("mode"), "reactor");
        // anything else is rejected with the legal set in the message
        let err = c.parse(&args(&["--mode", "fibers"])).unwrap_err().to_string();
        assert!(err.contains("fibers") && err.contains("reactor") && err.contains("threads"));
        // help names the choices
        assert!(c.help().contains("--mode <reactor|threads>"));
    }

    #[test]
    fn help_renders() {
        let h = cmd().help();
        assert!(h.contains("SUBCOMMANDS"));
        assert!(h.contains("--nodes"));
        assert!(h.contains("[default: 3]"));
    }
}
