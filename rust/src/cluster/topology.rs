//! Epoch-versioned, shareable cluster topology.
//!
//! [`Topology`] wraps the consistent-hash [`Ring`] behind interior
//! locking so membership can change at runtime while readers route:
//! every mutation ([`join`](Topology::join) /
//! [`decommission`](Topology::decommission)) bumps a monotone **epoch**
//! under the same write lock that changes the ring, so an epoch observed
//! before an op and re-read after it tells the caller whether routing
//! could have shifted underneath. Node ids are dense and never reused:
//! a decommissioned id simply stops owning ranges (exactly the DVV §4
//! stress case — retired actor ids linger in contexts, and causality
//! must survive the ownership transfer).
//!
//! Reads are allocation-free on the hot path:
//! [`replicas_into`](Topology::replicas_into) fills a caller-provided
//! buffer under one read lock, and
//! [`next_distinct`](Topology::next_distinct) resumes the ring walk
//! lazily for sloppy-quorum stand-in selection.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use crate::error::{Error, Result};

use super::ring::{NodeId, Ring};

/// The first epoch a fresh topology reports. Epochs only ever grow.
pub const INITIAL_EPOCH: u64 = 1;

#[derive(Debug)]
struct Inner {
    ring: Ring,
    /// `member[id]` — is the dense slot `id` an active member?
    member: Vec<bool>,
    /// Count of `true` entries (slots grow forever; the member count
    /// must not cost a scan per lookup or per churn cycle).
    live: usize,
    /// `zone[id]` — the DC each dense slot was placed in (zone 0 for
    /// flat clusters; slots keep their zone after decommission).
    zone: Vec<usize>,
    /// Use the zone-spreading walk for preference lists? Set by
    /// [`Topology::with_zones`]; flat clusters keep the plain walk so
    /// single-DC placement is byte-identical to pre-geo builds.
    zone_aware: bool,
}

impl Inner {
    /// Active member ids, ascending.
    fn members(&self) -> Vec<NodeId> {
        self.member
            .iter()
            .enumerate()
            .filter_map(|(id, &m)| m.then_some(id))
            .collect()
    }
}

/// A shared, epoch-versioned view of cluster membership and placement.
#[derive(Debug)]
pub struct Topology {
    inner: RwLock<Inner>,
    epoch: AtomicU64,
}

impl Topology {
    /// Build a topology of `nodes` initial members with `vnodes` ring
    /// points each, at [`INITIAL_EPOCH`].
    pub fn new(nodes: usize, vnodes: usize) -> Result<Topology> {
        let ring = Ring::new(nodes, vnodes)?;
        Ok(Topology {
            inner: RwLock::new(Inner {
                ring,
                member: vec![true; nodes],
                live: nodes,
                zone: vec![0; nodes],
                zone_aware: false,
            }),
            epoch: AtomicU64::new(INITIAL_EPOCH),
        })
    }

    /// Build a **zone-aware** topology: node `i` lives in DC `zones[i]`,
    /// and preference lists use the zone-spreading walk
    /// ([`Ring::replicas_into_zoned`]) so the first `min(n, #zones)`
    /// replicas of every key land in distinct DCs.
    pub fn with_zones(zones: &[usize], vnodes: usize) -> Result<Topology> {
        let ring = Ring::new(zones.len(), vnodes)?;
        Ok(Topology {
            inner: RwLock::new(Inner {
                ring,
                member: vec![true; zones.len()],
                live: zones.len(),
                zone: zones.to_vec(),
                zone_aware: true,
            }),
            epoch: AtomicU64::new(INITIAL_EPOCH),
        })
    }

    /// Is the zone-spreading placement walk active?
    pub fn is_zone_aware(&self) -> bool {
        self.inner.read().unwrap().zone_aware
    }

    /// The DC a dense slot was placed in (zone 0 for unknown ids and
    /// flat clusters). Decommissioned slots keep their zone — retired
    /// actor ids linger in contexts, and audits still ask where they
    /// lived.
    pub fn zone_of(&self, id: NodeId) -> usize {
        self.inner.read().unwrap().zone.get(id).copied().unwrap_or(0)
    }

    /// Number of distinct zones among **active** members.
    pub fn zone_count(&self) -> usize {
        let inner = self.inner.read().unwrap();
        let mut zones: Vec<usize> = inner
            .member
            .iter()
            .enumerate()
            .filter_map(|(id, &m)| m.then(|| inner.zone.get(id).copied().unwrap_or(0)))
            .collect();
        zones.sort_unstable();
        zones.dedup();
        zones.len()
    }

    /// Active member ids in `zone`, ascending.
    pub fn members_in_zone(&self, zone: usize) -> Vec<NodeId> {
        let inner = self.inner.read().unwrap();
        inner
            .member
            .iter()
            .enumerate()
            .filter_map(|(id, &m)| {
                (m && inner.zone.get(id).copied().unwrap_or(0) == zone).then_some(id)
            })
            .collect()
    }

    /// Current membership epoch. Monotone: bumped by exactly one per
    /// successful [`join`](Topology::join) /
    /// [`decommission`](Topology::decommission).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Total dense node slots ever allocated (members + decommissioned).
    pub fn slots(&self) -> usize {
        self.inner.read().unwrap().member.len()
    }

    /// Number of active members.
    pub fn member_count(&self) -> usize {
        self.inner.read().unwrap().live
    }

    /// Active member ids, ascending.
    pub fn members(&self) -> Vec<NodeId> {
        self.inner.read().unwrap().members()
    }

    /// Is `id` an active member?
    pub fn is_member(&self, id: NodeId) -> bool {
        self.inner.read().unwrap().member.get(id).copied().unwrap_or(false)
    }

    /// One consistent `(epoch, slots, members)` view, taken under a
    /// single read lock — what the admin plane reports. (Epoch bumps
    /// happen inside the write lock, so the epoch read here always
    /// matches the membership read with it; three separate getter calls
    /// could interleave with a bump and pair epoch `N` with epoch-`N+1`
    /// members.)
    pub fn snapshot(&self) -> (u64, usize, Vec<NodeId>) {
        let inner = self.inner.read().unwrap();
        (self.epoch.load(Ordering::Acquire), inner.member.len(), inner.members())
    }

    /// Admit a new node: allocates the next dense id, places its vnodes,
    /// and bumps the epoch. Returns `(new id, new epoch)`.
    pub fn join(&self) -> (NodeId, u64) {
        self.join_in_zone(0)
    }

    /// [`join`](Topology::join), placing the newcomer in DC `zone`.
    pub fn join_in_zone(&self, zone: usize) -> (NodeId, u64) {
        let mut inner = self.inner.write().unwrap();
        let id = inner.ring.add_node();
        debug_assert_eq!(id, inner.member.len(), "ring ids stay dense");
        inner.member.push(true);
        inner.zone.push(zone);
        inner.live += 1;
        // bump inside the write lock: an epoch can never be observed
        // with a ring older than the one that produced it
        let epoch = self.epoch.fetch_add(1, Ordering::AcqRel) + 1;
        (id, epoch)
    }

    /// Retire a member: its vnodes leave the ring (keys re-route to
    /// successors), the id is never reused, and the epoch bumps. Returns
    /// the new epoch. Refuses to retire a non-member or the last member.
    pub fn decommission(&self, id: NodeId) -> Result<u64> {
        let mut inner = self.inner.write().unwrap();
        if !inner.member.get(id).copied().unwrap_or(false) {
            return Err(Error::Config(format!("node {id} is not an active member")));
        }
        if inner.live <= 1 {
            return Err(Error::Config("cannot decommission the last member".into()));
        }
        inner.ring.remove_node(id);
        inner.member[id] = false;
        inner.live -= 1;
        Ok(self.epoch.fetch_add(1, Ordering::AcqRel) + 1)
    }

    /// Allocation-free preference-list lookup: clear `out` and fill it
    /// with the first `n` distinct member replicas for `key`, under one
    /// read lock.
    pub fn replicas_into(&self, key: u64, n: usize, out: &mut Vec<NodeId>) {
        let inner = self.inner.read().unwrap();
        if inner.zone_aware {
            inner.ring.replicas_into_zoned(key, n, &inner.zone, out);
        } else {
            inner.ring.replicas_into(key, n, out);
        }
    }

    /// Allocating convenience form of
    /// [`replicas_into`](Topology::replicas_into) (tests, admin paths).
    pub fn replicas_for(&self, key: u64, n: usize) -> Vec<NodeId> {
        let mut out = Vec::with_capacity(n);
        self.replicas_into(key, n, &mut out);
        out
    }

    /// Primary (coordinator-preferred) replica for `key`.
    pub fn primary_for(&self, key: u64) -> Option<NodeId> {
        self.inner.read().unwrap().ring.primary_for(key)
    }

    /// Resume the preference walk for `key` past the nodes in `seen`
    /// (see [`Ring::next_distinct`]): the stand-in search of the sloppy
    /// quorum pulls candidates one at a time instead of materializing a
    /// full-cluster preference list per faulted write.
    pub fn next_distinct(&self, key: u64, seen: &mut Vec<NodeId>) -> Option<NodeId> {
        self.inner.read().unwrap().ring.next_distinct(key, seen)
    }

    /// Run a closure against the underlying ring snapshot (benches,
    /// invariant tests). The read lock is held for the closure's
    /// duration — keep it short.
    pub fn with_ring<R>(&self, f: impl FnOnce(&Ring) -> R) -> R {
        f(&self.inner.read().unwrap().ring)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_topology_reports_initial_state() {
        let t = Topology::new(3, 32).unwrap();
        assert_eq!(t.epoch(), INITIAL_EPOCH);
        assert_eq!(t.slots(), 3);
        assert_eq!(t.member_count(), 3);
        assert_eq!(t.members(), vec![0, 1, 2]);
        assert!(t.is_member(2));
        assert!(!t.is_member(3));
    }

    #[test]
    fn join_allocates_dense_ids_and_bumps_epoch() {
        let t = Topology::new(2, 32).unwrap();
        let (id, epoch) = t.join();
        assert_eq!(id, 2);
        assert_eq!(epoch, INITIAL_EPOCH + 1);
        assert_eq!(t.epoch(), epoch);
        assert_eq!(t.members(), vec![0, 1, 2]);
        // routing reaches the newcomer
        let owns: usize = (0..2000u64)
            .filter(|&k| t.primary_for(k) == Some(2))
            .count();
        assert!(owns > 0, "joined node owns key ranges");
    }

    #[test]
    fn decommission_reroutes_and_never_reuses_ids() {
        let t = Topology::new(3, 32).unwrap();
        let epoch = t.decommission(1).unwrap();
        assert_eq!(epoch, INITIAL_EPOCH + 1);
        assert!(!t.is_member(1));
        assert_eq!(t.member_count(), 2);
        assert_eq!(t.slots(), 3, "the id slot stays allocated");
        for key in 0..200u64 {
            assert!(!t.replicas_for(key, 3).contains(&1));
        }
        // the next join takes a fresh id, not the retired one
        let (id, _) = t.join();
        assert_eq!(id, 3);
    }

    #[test]
    fn decommission_rejects_non_members_and_the_last_member() {
        let t = Topology::new(2, 16).unwrap();
        assert!(t.decommission(7).is_err(), "unknown id");
        t.decommission(0).unwrap();
        assert!(t.decommission(0).is_err(), "already retired");
        assert!(t.decommission(1).is_err(), "last member must stay");
        assert_eq!(t.member_count(), 1);
    }

    #[test]
    fn epoch_is_monotone_across_interleaved_changes() {
        let t = Topology::new(2, 16).unwrap();
        let mut last = t.epoch();
        for _ in 0..5 {
            let (_, e) = t.join();
            assert_eq!(e, last + 1);
            last = e;
        }
        for id in 0..4 {
            let e = t.decommission(id).unwrap();
            assert_eq!(e, last + 1);
            last = e;
        }
    }

    #[test]
    fn zoned_topology_spreads_preference_lists() {
        let t = Topology::with_zones(&[0, 0, 0, 1, 1, 1], 64).unwrap();
        assert!(t.is_zone_aware());
        assert_eq!(t.zone_count(), 2);
        assert_eq!(t.members_in_zone(1), vec![3, 4, 5]);
        assert_eq!(t.zone_of(4), 1);
        assert_eq!(t.zone_of(99), 0, "unknown ids default to zone 0");
        for key in 0..200u64 {
            let reps = t.replicas_for(key, 3);
            let zones: std::collections::HashSet<_> =
                reps.iter().map(|&n| t.zone_of(n)).collect();
            assert_eq!(zones.len(), 2, "key {key}: {reps:?} stuck in one DC");
        }
    }

    #[test]
    fn flat_topology_placement_is_unchanged_by_zone_plumbing() {
        let t = Topology::new(5, 32).unwrap();
        assert!(!t.is_zone_aware());
        assert_eq!(t.zone_count(), 1);
        let ring = Ring::new(5, 32).unwrap();
        for key in 0..200u64 {
            assert_eq!(t.replicas_for(key, 3), ring.replicas_for(key, 3));
        }
    }

    #[test]
    fn join_in_zone_records_placement_and_bumps_epoch() {
        let t = Topology::with_zones(&[0, 1], 32).unwrap();
        let (id, epoch) = t.join_in_zone(2);
        assert_eq!((id, epoch), (2, INITIAL_EPOCH + 1));
        assert_eq!(t.zone_of(id), 2);
        assert_eq!(t.zone_count(), 3);
        // plain join lands in zone 0 and zones survive decommission
        let (id2, _) = t.join();
        assert_eq!(t.zone_of(id2), 0);
        t.decommission(id).unwrap();
        assert_eq!(t.zone_of(id), 2, "retired slots keep their zone");
        assert_eq!(t.zone_count(), 2);
    }

    #[test]
    fn concurrent_readers_and_churn_do_not_panic() {
        use std::sync::Arc;
        let t = Arc::new(Topology::new(3, 32).unwrap());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let t = Arc::clone(&t);
            handles.push(std::thread::spawn(move || {
                let mut buf = Vec::new();
                for k in 0..2000u64 {
                    t.replicas_into(k, 3, &mut buf);
                    assert!(!buf.is_empty());
                    for &n in &buf {
                        assert!(n < t.slots());
                    }
                }
            }));
        }
        for _ in 0..3 {
            let (id, _) = t.join();
            let _ = t.decommission(id);
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
