//! Cluster topology: consistent-hashing ring, membership, replica
//! placement — the Dynamo substrate of §2 ("the approach used to decide
//! which nodes will replicate a given key (e.g., consistent hashing)").

pub mod ring;
pub mod topology;

pub use ring::{NodeId, Ring};
pub use topology::Topology;
