//! Consistent-hashing ring with virtual nodes.
//!
//! Keys hash onto a 64-bit circle; each physical node owns `vnodes`
//! points. The preference list for a key is the first `n` *distinct*
//! nodes walking clockwise from the key's hash — Dynamo's placement rule.

use crate::error::{Error, Result};

/// Physical node index within the cluster (dense, 0-based).
pub type NodeId = usize;

/// 64-bit mix hash (splitmix64 finalizer) — stable across runs.
#[inline]
pub fn hash64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Hash a string key into the ring's key space.
pub fn hash_str(s: &str) -> u64 {
    // FNV-1a then mix — good enough for routing, stable, dependency-free.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    hash64(h)
}

/// A consistent-hash ring over dense node ids.
#[derive(Debug, Clone)]
pub struct Ring {
    /// Sorted (point, node) pairs.
    points: Vec<(u64, NodeId)>,
    nodes: usize,
    vnodes: usize,
}

impl Ring {
    /// Build a ring of `nodes` physical nodes with `vnodes` points each.
    pub fn new(nodes: usize, vnodes: usize) -> Result<Ring> {
        if nodes == 0 || vnodes == 0 {
            return Err(Error::Config("ring needs nodes >= 1 and vnodes >= 1".into()));
        }
        let mut ring = Ring { points: Vec::new(), nodes: 0, vnodes };
        for _ in 0..nodes {
            ring.add_node();
        }
        Ok(ring)
    }

    /// Number of physical nodes.
    pub fn node_count(&self) -> usize {
        self.nodes
    }

    /// Add a new physical node (id = current count) and place its vnodes.
    pub fn add_node(&mut self) -> NodeId {
        let id = self.nodes;
        self.nodes += 1;
        for v in 0..self.vnodes {
            let point = hash64((id as u64) << 32 | v as u64 | 0xF00D_0000_0000_0000);
            self.points.push((point, id));
        }
        self.points.sort_unstable();
        id
    }

    /// Remove a node's vnodes (keys re-route to successors). Node ids are
    /// not compacted; the id simply stops owning ranges.
    pub fn remove_node(&mut self, id: NodeId) {
        self.points.retain(|&(_, n)| n != id);
    }

    /// The first `n` distinct replica nodes for `key`, clockwise from its
    /// hash (the preference list).
    pub fn replicas_for(&self, key: u64, n: usize) -> Vec<NodeId> {
        let mut out = Vec::with_capacity(n);
        self.replicas_into(key, n, &mut out);
        out
    }

    /// Allocation-free preference-list lookup: clear `out` and fill it
    /// with the first `n` distinct replica nodes for `key`. The buffer is
    /// caller-provided so hot paths can reuse one allocation across ops.
    pub fn replicas_into(&self, key: u64, n: usize, out: &mut Vec<NodeId>) {
        out.clear();
        if self.points.is_empty() || n == 0 {
            return;
        }
        let h = hash64(key);
        let start = match self.points.binary_search_by_key(&h, |&(p, _)| p) {
            Ok(i) | Err(i) => i,
        };
        for step in 0..self.points.len() {
            let (_, node) = self.points[(start + step) % self.points.len()];
            if !out.contains(&node) {
                out.push(node);
                if out.len() == n {
                    break;
                }
            }
        }
    }

    /// Zone-aware preference list: the same clockwise walk as
    /// [`replicas_into`](Ring::replicas_into), but the first pass only
    /// accepts nodes from zones not yet represented, so the first
    /// `min(n, #reachable zones)` replicas land in distinct DCs. A
    /// second pass fills any remaining slots with the next distinct
    /// nodes in plain walk order (covers `n` > zone count, or one zone
    /// owning most of the circle). `zone_of[id]` maps a node to its
    /// zone; ids beyond the slice default to zone 0. Both passes share
    /// the unzoned walk order, so the primary replica is identical
    /// under either policy.
    pub fn replicas_into_zoned(
        &self,
        key: u64,
        n: usize,
        zone_of: &[usize],
        out: &mut Vec<NodeId>,
    ) {
        out.clear();
        if self.points.is_empty() || n == 0 {
            return;
        }
        let h = hash64(key);
        let start = match self.points.binary_search_by_key(&h, |&(p, _)| p) {
            Ok(i) | Err(i) => i,
        };
        let zone = |node: NodeId| zone_of.get(node).copied().unwrap_or(0);
        let mut zones_seen: Vec<usize> = Vec::new();
        for step in 0..self.points.len() {
            let (_, node) = self.points[(start + step) % self.points.len()];
            if !out.contains(&node) && !zones_seen.contains(&zone(node)) {
                zones_seen.push(zone(node));
                out.push(node);
                if out.len() == n {
                    return;
                }
            }
        }
        for step in 0..self.points.len() {
            let (_, node) = self.points[(start + step) % self.points.len()];
            if !out.contains(&node) {
                out.push(node);
                if out.len() == n {
                    return;
                }
            }
        }
    }

    /// Allocating convenience form of
    /// [`replicas_into_zoned`](Ring::replicas_into_zoned).
    pub fn replicas_for_zoned(&self, key: u64, n: usize, zone_of: &[usize]) -> Vec<NodeId> {
        let mut out = Vec::with_capacity(n);
        self.replicas_into_zoned(key, n, zone_of, &mut out);
        out
    }

    /// Resume the clockwise walk for `key` past the nodes already in
    /// `seen`: the next distinct node is pushed onto `seen` and returned,
    /// or `None` when every ring node is already in `seen`. Iterating
    /// this is how the sloppy-quorum stand-in search extends a preference
    /// list lazily instead of materializing the full-cluster list.
    pub fn next_distinct(&self, key: u64, seen: &mut Vec<NodeId>) -> Option<NodeId> {
        if self.points.is_empty() {
            return None;
        }
        let h = hash64(key);
        let start = match self.points.binary_search_by_key(&h, |&(p, _)| p) {
            Ok(i) | Err(i) => i,
        };
        for step in 0..self.points.len() {
            let (_, node) = self.points[(start + step) % self.points.len()];
            if !seen.contains(&node) {
                seen.push(node);
                return Some(node);
            }
        }
        None
    }

    /// Primary (coordinator-preferred) replica for `key`.
    pub fn primary_for(&self, key: u64) -> Option<NodeId> {
        self.replicas_for(key, 1).first().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preference_list_is_distinct_and_sized() {
        let ring = Ring::new(6, 64).unwrap();
        for key in 0..200u64 {
            let reps = ring.replicas_for(key, 3);
            assert_eq!(reps.len(), 3);
            let mut sorted = reps.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 3, "duplicates in {reps:?}");
        }
    }

    #[test]
    fn placement_is_stable() {
        let r1 = Ring::new(5, 32).unwrap();
        let r2 = Ring::new(5, 32).unwrap();
        for key in 0..100u64 {
            assert_eq!(r1.replicas_for(key, 3), r2.replicas_for(key, 3));
        }
    }

    #[test]
    fn load_is_roughly_balanced() {
        let ring = Ring::new(4, 128).unwrap();
        let mut counts = [0usize; 4];
        for key in 0..8000u64 {
            counts[ring.primary_for(key).unwrap()] += 1;
        }
        for &c in &counts {
            assert!(
                (1000..3500).contains(&c),
                "imbalanced primary load: {counts:?}"
            );
        }
    }

    #[test]
    fn adding_node_moves_limited_keys() {
        let mut ring = Ring::new(4, 128).unwrap();
        let before: Vec<_> = (0..2000u64).map(|k| ring.primary_for(k).unwrap()).collect();
        ring.add_node();
        let moved = (0..2000u64)
            .filter(|&k| ring.primary_for(k).unwrap() != before[k as usize])
            .count();
        // ideal is 1/5 = 400; allow generous slack
        assert!(moved > 100 && moved < 900, "moved {moved}");
    }

    #[test]
    fn removing_node_reroutes_to_survivors() {
        let mut ring = Ring::new(3, 64).unwrap();
        ring.remove_node(1);
        for key in 0..200u64 {
            let reps = ring.replicas_for(key, 2);
            assert!(!reps.contains(&1));
            assert_eq!(reps.len(), 2);
        }
    }

    #[test]
    fn replicas_capped_by_cluster_size() {
        let ring = Ring::new(2, 16).unwrap();
        assert_eq!(ring.replicas_for(7, 5).len(), 2);
    }

    #[test]
    fn bad_configs_rejected() {
        assert!(Ring::new(0, 8).is_err());
        assert!(Ring::new(3, 0).is_err());
    }

    #[test]
    fn hash_str_stable_and_spread() {
        assert_eq!(hash_str("key1"), hash_str("key1"));
        assert_ne!(hash_str("key1"), hash_str("key2"));
    }

    #[test]
    fn replicas_into_matches_replicas_for_and_reuses_buffer() {
        let ring = Ring::new(6, 64).unwrap();
        let mut buf = Vec::new();
        for key in 0..300u64 {
            ring.replicas_into(key, 3, &mut buf);
            assert_eq!(buf, ring.replicas_for(key, 3), "key {key}");
        }
        // the buffer is cleared, not accumulated
        ring.replicas_into(7, 2, &mut buf);
        assert_eq!(buf.len(), 2);
    }

    #[test]
    fn next_distinct_extends_the_preference_list_in_walk_order() {
        let ring = Ring::new(5, 64).unwrap();
        for key in 0..100u64 {
            let full = ring.replicas_for(key, 5);
            let mut seen = ring.replicas_for(key, 2);
            let mut resumed = seen.clone();
            while let Some(n) = ring.next_distinct(key, &mut seen) {
                resumed.push(n);
            }
            assert_eq!(resumed, full, "key {key}: lazy walk = materialized walk");
            assert!(ring.next_distinct(key, &mut seen).is_none(), "walk exhausts");
        }
    }

    #[test]
    fn zoned_walk_spreads_replicas_across_zones() {
        let ring = Ring::new(6, 64).unwrap();
        let zones = [0, 0, 0, 1, 1, 2]; // 3 DCs of uneven size
        for key in 0..300u64 {
            let reps = ring.replicas_for_zoned(key, 3, &zones);
            assert_eq!(reps.len(), 3);
            let mut zs: Vec<_> = reps.iter().map(|&n| zones[n]).collect();
            zs.sort_unstable();
            zs.dedup();
            assert_eq!(zs.len(), 3, "key {key}: replicas {reps:?} not zone-spread");
        }
    }

    #[test]
    fn zoned_walk_shares_primary_with_plain_walk() {
        let ring = Ring::new(6, 64).unwrap();
        let zones = [0, 1, 0, 1, 0, 1];
        for key in 0..300u64 {
            assert_eq!(
                ring.replicas_for_zoned(key, 3, &zones)[0],
                ring.primary_for(key).unwrap(),
                "key {key}"
            );
        }
    }

    #[test]
    fn zoned_walk_fills_past_zone_count_with_distinct_nodes() {
        let ring = Ring::new(5, 64).unwrap();
        let zones = [0, 0, 0, 0, 1]; // only 2 zones but n = 4
        for key in 0..200u64 {
            let reps = ring.replicas_for_zoned(key, 4, &zones);
            assert_eq!(reps.len(), 4, "second pass fills the list");
            let mut sorted = reps.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 4, "duplicates in {reps:?}");
            let zs: Vec<_> = reps[..2].iter().map(|&n| zones[n]).collect();
            assert_ne!(zs[0], zs[1], "first two span both zones: {reps:?}");
        }
    }

    #[test]
    fn next_distinct_skips_removed_nodes() {
        let mut ring = Ring::new(4, 64).unwrap();
        ring.remove_node(2);
        let mut seen = Vec::new();
        while let Some(n) = ring.next_distinct(9, &mut seen) {
            assert_ne!(n, 2, "removed node never surfaces");
        }
        assert_eq!(seen.len(), 3);
    }
}
