//! Metric accounting for simulated runs: op counts, message counts,
//! latency histograms, anomaly tallies (lost updates, false concurrency)
//! and metadata-size samples — everything E6/E7/E9 report.

use std::fmt;

/// A log-bucketed latency histogram (µs), constant memory.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) µs; bucket 0 is [0, 2).
    buckets: [u64; 40],
    count: u64,
    sum: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: [0; 40], count: 0, sum: 0, max: 0 }
    }
}

impl Histogram {
    /// New empty histogram.
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record a sample (µs).
    pub fn record(&mut self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(39);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += us;
        self.max = self.max.max(us);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean µs.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Max µs.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate percentile (bucket upper bound), p in [0,1].
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((self.count as f64) * p).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

impl fmt::Display for Histogram {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "n={} mean={:.1}µs p50={}µs p99={}µs max={}µs",
            self.count,
            self.mean(),
            self.percentile(0.50),
            self.percentile(0.99),
            self.max
        )
    }
}

/// Counters and samples collected by a simulated run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Completed GET operations.
    pub gets: u64,
    /// Completed PUT operations.
    pub puts: u64,
    /// Operations that failed (quorum not met / node down).
    pub failed_ops: u64,
    /// Replication / coordination messages sent.
    pub messages: u64,
    /// Messages dropped by the network model.
    pub dropped_messages: u64,
    /// Anti-entropy exchanges performed.
    pub ae_rounds: u64,
    /// Key-states merged during anti-entropy.
    pub ae_keys_synced: u64,
    /// Hash-tree digests compared during anti-entropy rounds (the cost
    /// of divergence *detection* under `antientropy.merkle`; 0 when the
    /// scan path is selected).
    pub ae_digests_compared: u64,
    /// Cross-DC shipper batches sent (geo-replication; 0 when flat).
    pub ship_batches: u64,
    /// Key-states carried by cross-DC shipper batches.
    pub ship_keys: u64,

    /// Concurrent updates silently destroyed (E6's headline anomaly):
    /// a value was removed although no surviving value causally covers it.
    pub lost_updates: u64,
    /// Values correctly superseded by a causally later value.
    pub correct_supersessions: u64,
    /// Sibling pairs returned by GETs that were in fact causally ordered
    /// (false concurrency: extra reconciliation work for clients).
    pub false_concurrent_pairs: u64,
    /// Sibling pairs returned by GETs that were genuinely concurrent.
    pub true_concurrent_pairs: u64,

    /// GET latency (simulated µs).
    pub get_latency: Histogram,
    /// PUT latency (simulated µs).
    pub put_latency: Histogram,

    /// Causality metadata bytes currently stored, sampled at run end.
    pub metadata_bytes: u64,
    /// Context bytes shipped to clients, accumulated.
    pub context_bytes: u64,
    /// Largest sibling set ever observed.
    pub max_siblings: usize,
}

impl Metrics {
    /// New zeroed metrics.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Total client ops completed.
    pub fn ops(&self) -> u64 {
        self.gets + self.puts
    }

    /// One-line summary used by examples and benches.
    pub fn summary(&self) -> String {
        format!(
            "ops={} (get={} put={} failed={}) msgs={} ship={}/{} lost_updates={} \
             false_conc={} true_conc={} max_siblings={} metadata={}B",
            self.ops(),
            self.gets,
            self.puts,
            self.failed_ops,
            self.messages,
            self.ship_batches,
            self.ship_keys,
            self.lost_updates,
            self.false_concurrent_pairs,
            self.true_concurrent_pairs,
            self.max_siblings,
            self.metadata_bytes
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let mut h = Histogram::new();
        for us in [1u64, 2, 4, 8, 100, 1000, 10_000] {
            h.record(us);
        }
        assert_eq!(h.count(), 7);
        assert!(h.percentile(0.5) <= h.percentile(0.99));
        assert!(h.mean() > 0.0);
        assert_eq!(h.max(), 10_000);
    }

    #[test]
    fn histogram_zero_and_empty() {
        let mut h = Histogram::new();
        assert_eq!(h.percentile(0.5), 0);
        h.record(0);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn histogram_merge_accumulates() {
        let mut a = Histogram::new();
        a.record(10);
        let mut b = Histogram::new();
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), 1000);
    }

    #[test]
    fn metrics_summary_contains_counts() {
        let mut m = Metrics::new();
        m.gets = 5;
        m.puts = 3;
        m.lost_updates = 2;
        let s = m.summary();
        assert!(s.contains("get=5") && s.contains("lost_updates=2"));
        assert_eq!(m.ops(), 8);
    }
}
