//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the store, simulator, runtime, and tooling layers.
#[derive(Debug, Error)]
pub enum Error {
    /// A key had no replica nodes (ring misconfiguration).
    #[error("no replica nodes for key {0:?}")]
    NoReplicas(String),

    /// Not enough replicas answered within the quorum window.
    #[error("quorum not met: got {got}, needed {needed}")]
    QuorumNotMet { got: usize, needed: usize },

    /// A request was routed to a node that is not a replica for the key.
    #[error("node {node} is not a replica for key {key:?}")]
    NotAReplica { node: String, key: String },

    /// The node is crashed / partitioned away.
    #[error("node {0} unavailable")]
    Unavailable(String),

    /// Conditional-write rejection (Coda/CVS-style semantics, §3.2).
    #[error("conditional write rejected: context is stale")]
    StaleContext,

    /// Artifact manifest / HLO loading problems.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// XLA/PJRT runtime failure.
    #[error("xla runtime: {0}")]
    Xla(String),

    /// Configuration file / CLI parse errors.
    #[error("config: {0}")]
    Config(String),

    /// Wire-protocol decode errors (TCP server mode).
    #[error("protocol: {0}")]
    Protocol(String),

    /// An error the remote server reported over the wire (the operation
    /// itself failed; the connection and framing are fine).
    #[error("remote: {0}")]
    Remote(String),

    /// Codec errors for clock serialization.
    #[error("codec: {0}")]
    Codec(String),

    /// A typed CRDT op addressed a key holding a different datatype
    /// (e.g. `INCR` on a set key); see [`crate::kernel::crdt`].
    #[error("wrong datatype: expected {expected}, found {found}")]
    WrongType {
        /// The datatype the key actually holds.
        expected: &'static str,
        /// The datatype the op (or incoming state) carried.
        found: &'static str,
    },

    /// Generic I/O.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
