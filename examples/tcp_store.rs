//! Serve-and-query demo: start the TCP store in-process, talk to it over
//! a real socket with the text protocol, and exercise the sibling /
//! reconcile flow a Riak-style client would see.
//!
//! Run: `cargo run --release --example tcp_store`

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use dvvstore::server::protocol::hex_encode;
use dvvstore::server::{tcp::Server, LocalCluster};

fn send(w: &mut TcpStream, line: &str) {
    w.write_all(line.as_bytes()).unwrap();
    w.write_all(b"\n").unwrap();
}

fn recv(r: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    line.trim_end().to_string()
}

fn main() -> dvvstore::Result<()> {
    let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2)?);
    let server = Server::start("127.0.0.1:0", cluster)?;
    println!("serving on {}", server.addr());

    let stream = TcpStream::connect(server.addr())?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;

    // two sessions write the same key concurrently (blind writes)
    send(&mut writer, &format!("PUT cart:42 {}", hex_encode(b"apples")));
    assert_eq!(recv(&mut reader), "OK");
    send(&mut writer, &format!("PUT cart:42 {}", hex_encode(b"bananas")));
    assert_eq!(recv(&mut reader), "OK");

    // a read sees both siblings plus the causal context
    send(&mut writer, "GET cart:42");
    let header = recv(&mut reader);
    println!("< {header}");
    assert!(header.starts_with("VALUES 2 "));
    let ctx = header.split_whitespace().nth(2).unwrap().to_string();
    for _ in 0..2 {
        println!("< {}", recv(&mut reader));
    }

    // the shopper merges the carts and writes back with the context
    send(
        &mut writer,
        &format!("PUT cart:42 {} {ctx}", hex_encode(b"apples+bananas")),
    );
    assert_eq!(recv(&mut reader), "OK");
    send(&mut writer, "GET cart:42");
    let header = recv(&mut reader);
    println!("< {header}");
    assert!(header.starts_with("VALUES 1 "), "reconciled to one version");
    println!("< {}", recv(&mut reader));

    send(&mut writer, "STATS");
    println!("< {}", recv(&mut reader));
    send(&mut writer, "QUIT");
    assert_eq!(recv(&mut reader), "BYE");
    server.shutdown();
    println!("tcp_store OK");
    Ok(())
}
