//! Anti-entropy acceleration demo: the full three-layer stack.
//!
//! Two replica stores diverge over thousands of keys; the divergent-key
//! worklist is synced twice — once with the scalar rust kernel, once with
//! the AOT-compiled Pallas dominance kernel via PJRT — asserting identical
//! results and reporting both timings (E10's headline).
//!
//! Requires `make artifacts` (the AOT step). Python is *not* executed
//! here: the HLO was lowered at build time.
//!
//! Run: `make artifacts && cargo run --release --example antientropy_accel`

use dvvstore::antientropy::{diff_pairs, sync_scalar, sync_xla};
use dvvstore::bench_support::{fmt_ns, time_once};
use dvvstore::clocks::Actor;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::kernel::{Mechanism, Val, WriteMeta};
use dvvstore::runtime::batch::SlotMap;
use dvvstore::runtime::{artifact, XlaEngine};
use dvvstore::store::KeyStore;
use dvvstore::testkit::Rng;

const KEYS: u64 = 4000;
const REPLICAS: usize = 8;

fn main() -> dvvstore::Result<()> {
    let dir = artifact::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts not found at {dir:?} — run `make artifacts` first");
        std::process::exit(1);
    }

    // Build two replicas that saw different subsets of client writes.
    let mech = DvvMech;
    let mut local = KeyStore::new(mech);
    let mut remote = KeyStore::new(mech);
    let mut rng = Rng::new(7);
    let mut val_id = 0u64;
    for key in 0..KEYS {
        for _ in 0..rng.range(1, 3) {
            val_id += 1;
            let coord = Actor::server(rng.below(REPLICAS as u64) as u32);
            let meta = WriteMeta::basic(Actor::client(rng.below(64) as u32));
            let target = if rng.chance(0.5) { &mut local } else { &mut remote };
            let (_, ctx) = target.read(key);
            let ctx = if rng.chance(0.5) { ctx } else { Default::default() };
            target.write(key, &ctx, Val::new(val_id, 64), coord, &meta);
        }
    }

    let pairs = diff_pairs(&local, &remote);
    let clocks: usize = pairs.iter().map(|p| p.local.len() + p.remote.len()).sum();
    println!("divergent keys: {} ({clocks} clocks to compare)", pairs.len());

    // scalar path
    let (scalar_merged, scalar_t) = time_once(|| sync_scalar(&pairs));
    println!("scalar kernel sync: {}", fmt_ns(scalar_t.as_nanos() as f64));

    // XLA path (compile once, then measure execution)
    let mut engine = XlaEngine::open(&dir)?;
    let slots = SlotMap::dense(REPLICAS);
    let ((), compile_t) = time_once(|| {
        engine.compile_all().expect("compile artifacts");
    });
    println!("PJRT compile (one-time): {}", fmt_ns(compile_t.as_nanos() as f64));
    let (xla_merged, xla_t) = time_once(|| sync_xla(&mut engine, &pairs, &slots).unwrap());
    println!("XLA bulk-dominance sync: {}", fmt_ns(xla_t.as_nanos() as f64));

    // identical semantics
    let canon = |mut m: dvvstore::antientropy::Merged| {
        m.sort_by_key(|(k, _)| *k);
        m.into_iter()
            .map(|(k, set)| {
                let mut ids: Vec<u64> = set.iter().map(|(_, v)| v.id).collect();
                ids.sort_unstable();
                (k, ids)
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(canon(scalar_merged), canon(xla_merged), "paths must agree");
    println!(
        "result identical across paths; speedup(execute-only): {:.2}x",
        scalar_t.as_secs_f64() / xla_t.as_secs_f64()
    );
    println!("antientropy_accel OK");
    Ok(())
}
