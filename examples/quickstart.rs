//! Quickstart: the public API in five minutes.
//!
//! Shows the paper's core loop — GET returns siblings + a causal context,
//! PUT with that context supersedes exactly what was read — first against
//! a bare mechanism (the ~100-LoC integration surface), then against the
//! in-process replicated cluster.
//!
//! Run: `cargo run --release --example quickstart`

use dvvstore::clocks::Actor;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::kernel::{Mechanism, Val, WriteMeta};
use dvvstore::server::LocalCluster;

fn main() -> dvvstore::Result<()> {
    // ------------------------------------------------------------------
    // 1. The mechanism alone: the paper's §5 update/sync kernel.
    // ------------------------------------------------------------------
    let mech = DvvMech;
    let mut replica_state = Vec::new(); // a replica node's state for one key
    let coordinator = Actor::server(1); // "Rb" in the paper's figures
    let meta = WriteMeta::basic(Actor::client(0));

    // two blind writes (empty context) -> two siblings, as in Figure 7
    mech.write(&mut replica_state, &Default::default(), Val::new(1, 0), coordinator, &meta);
    mech.write(&mut replica_state, &Default::default(), Val::new(2, 0), coordinator, &meta);
    let (siblings, context) = mech.read(&replica_state);
    println!("after two blind writes: {} siblings, context {context}", siblings.len());
    assert_eq!(siblings.len(), 2);

    // a write carrying the read context supersedes both
    mech.write(&mut replica_state, &context, Val::new(3, 0), coordinator, &meta);
    let (siblings, _) = mech.read(&replica_state);
    println!("after informed write:  {} sibling (reconciled)", siblings.len());
    assert_eq!(siblings, vec![Val::new(3, 0)]);

    // ------------------------------------------------------------------
    // 2. The replicated store: same semantics behind quorum get/put.
    // ------------------------------------------------------------------
    let cluster = LocalCluster::new(3, 3, 2, 2)?; // 3 replicas, N=3 R=2 W=2

    cluster.put("greeting", b"hello".to_vec(), &[])?;
    cluster.put("greeting", b"hallo".to_vec(), &[])?; // concurrent blind write
    let answer = cluster.get("greeting")?;
    println!(
        "cluster siblings: {:?}",
        answer.values.iter().map(|v| String::from_utf8_lossy(v)).collect::<Vec<_>>()
    );
    assert_eq!(answer.values.len(), 2);

    // reconcile via the context returned by GET
    cluster.put("greeting", b"hello world".to_vec(), &answer.context)?;
    let answer = cluster.get("greeting")?;
    assert_eq!(answer.values, vec![b"hello world".to_vec()]);
    println!("reconciled to: {:?}", String::from_utf8_lossy(&answer.values[0]));

    println!("quickstart OK");
    Ok(())
}
