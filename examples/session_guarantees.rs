//! Session guarantees and the stateless-client anomaly (§3.3, Figure 4).
//!
//! Runs the same workload twice under per-client version vectors — once
//! with stateful clients (own write counters: correct) and once with
//! stateless clients (server-side counter inference: loses updates when a
//! client switches coordinators) — then shows DVV is immune to the client
//! model because its identifiers are per-server.
//!
//! Run: `cargo run --release --example session_guarantees`

use dvvstore::config::StoreConfig;
use dvvstore::kernel::mechs::{ClientVvMech, DvvMech};
use dvvstore::sim::Sim;
use dvvstore::workload::{RandomWorkload, WorkloadSpec};

fn run<M: dvvstore::kernel::Mechanism>(
    mech: M,
    stateful: bool,
    seed: u64,
) -> dvvstore::Result<(u64, u64)> {
    let mut cfg = StoreConfig::default();
    cfg.cluster.nodes = 4;
    cfg.cluster.replication = 2;
    cfg.cluster.read_quorum = 1;
    cfg.cluster.write_quorum = 1;
    cfg.cluster.random_coordinator = true;
    // R=1/W=1, random coordinators, slow+lossy replication: the Figure 4
    // setting — a client's writes reach different coordinators before the
    // earlier write's replication does, so server-side counter inference
    // re-issues duplicate (client, seq) identifiers
    cfg.net.mean_latency_us = 5_000.0;
    cfg.net.drop_prob = 0.15;
    let spec = WorkloadSpec {
        keys: 8,
        zipf_theta: 0.8,
        put_fraction: 0.8,
        read_before_write: 0.4,
        mean_think_us: 300.0,
        ops_per_client: 150,
        value_len: 32,
    };
    let driver = Box::new(RandomWorkload::new(spec, 16));
    let mut sim = Sim::new(mech, cfg, 16, stateful, driver, seed)?;
    sim.start();
    sim.run(u64::MAX);
    sim.settle();
    Ok((sim.writes_issued(), sim.audit_permanently_lost()))
}

fn main() -> dvvstore::Result<()> {
    let seed = 404;
    println!("# session guarantees: per-client VVs vs DVV under both client models\n");
    println!("| mechanism | clients   | writes | permanently lost |");
    println!("|---|---|---|---|");

    let (w, lost_stateful) = run(ClientVvMech, true, seed)?;
    println!("| clientvv  | stateful  | {w} | {lost_stateful} |");

    let (w, lost_stateless) = run(ClientVvMech, false, seed)?;
    println!("| clientvv  | stateless | {w} | {lost_stateless} |");

    let (w, dvv_stateful) = run(DvvMech, true, seed)?;
    println!("| dvv       | stateful  | {w} | {dvv_stateful} |");

    let (w, dvv_stateless) = run(DvvMech, false, seed)?;
    println!("| dvv       | stateless | {w} | {dvv_stateless} |");

    // The paper's point, enforced:
    assert_eq!(lost_stateful, 0, "stateful per-client VVs are lossless");
    assert!(
        lost_stateless > 0,
        "stateless per-client VVs must exhibit the Figure 4 anomaly"
    );
    assert_eq!(dvv_stateful, 0);
    assert_eq!(dvv_stateless, 0, "DVV needs no client-side state at all");

    println!(
        "\nFigure 4 anomaly reproduced: stateless client-VV lost {lost_stateless} updates; \
         DVV lost none under either client model."
    );
    println!("session_guarantees OK");
    Ok(())
}
