//! End-to-end driver (DESIGN.md headline experiment): a 6-node cluster,
//! 100k-operation concurrent workload, every mechanism run on the *same*
//! deterministic interleaving, reporting the paper's claims as one table:
//!
//! * lossless mechanisms (causal histories, per-client VVs, DVV, DVVSet)
//!   lose **zero** updates;
//! * LWW / Lamport / per-server VVs destroy concurrent writes;
//! * DVV does it with metadata bounded by the replication degree, while
//!   per-client VVs grow with the client population.
//!
//! The run is recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example cluster_sim [seed]`

use dvvstore::bench_support::time_once;
use dvvstore::config::StoreConfig;
use dvvstore::kernel::mechs::{dispatch, MechVisitor};
use dvvstore::kernel::{MechKind, Mechanism};
use dvvstore::sim::Sim;
use dvvstore::workload::{RandomWorkload, WorkloadSpec};

const CLIENTS: usize = 32;
const OPS_PER_CLIENT: u64 = 320; // ≈ 100k total with chained informed writes

struct Run {
    seed: u64,
}

struct Row {
    name: &'static str,
    ops: u64,
    wall_ms: f64,
    sim_throughput: f64,
    lost: u64,
    lost_pct: f64,
    false_conc: u64,
    true_conc: u64,
    max_siblings: usize,
    metadata: u64,
    get_p50: u64,
    put_p50: u64,
}

impl MechVisitor for Run {
    type Out = dvvstore::Result<Row>;

    fn visit<M: Mechanism>(self, mech: M) -> Self::Out {
        let mut cfg = StoreConfig::default();
        cfg.cluster.nodes = 6;
        cfg.cluster.replication = 3;
        cfg.cluster.read_quorum = 2;
        cfg.cluster.write_quorum = 2;
        cfg.antientropy.period_us = 200_000;
        let spec = WorkloadSpec {
            keys: 200,
            zipf_theta: 0.9,
            put_fraction: 0.6,
            read_before_write: 0.5,
            mean_think_us: 800.0,
            ops_per_client: OPS_PER_CLIENT,
            value_len: 64,
        };
        let driver = Box::new(RandomWorkload::new(spec, CLIENTS));
        let mut sim = Sim::new(mech, cfg, CLIENTS, true, driver, self.seed)?;
        sim.start();
        let ((), wall) = time_once(|| sim.run(u64::MAX));
        sim.settle();
        let lost = sim.audit_permanently_lost();
        let writes = sim.writes_issued();
        Ok(Row {
            name: M::NAME,
            ops: sim.metrics.ops(),
            wall_ms: wall.as_secs_f64() * 1e3,
            sim_throughput: sim.metrics.ops() as f64 / wall.as_secs_f64(),
            lost,
            lost_pct: 100.0 * lost as f64 / writes.max(1) as f64,
            false_conc: sim.metrics.false_concurrent_pairs,
            true_conc: sim.metrics.true_concurrent_pairs,
            max_siblings: sim.metrics.max_siblings,
            metadata: sim.metrics.metadata_bytes,
            get_p50: sim.metrics.get_latency.percentile(0.5),
            put_p50: sim.metrics.put_latency.percentile(0.5),
        })
    }
}

fn main() -> dvvstore::Result<()> {
    let seed: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2010);
    println!(
        "# cluster_sim — 6 nodes, N=3 R=2 W=2, {CLIENTS} clients × {OPS_PER_CLIENT} ops, \
         zipf(0.9) over 200 keys, 50% informed writes, anti-entropy 200ms, seed {seed}\n"
    );
    println!(
        "| mechanism | ops | lost | lost% | false_conc | true_conc | max_sib | metadata(B) \
         | get_p50(µs) | put_p50(µs) | wall(ms) | sim_ops/s |"
    );
    println!("|---|---|---|---|---|---|---|---|---|---|---|---|");
    for kind in MechKind::ALL {
        let row = dispatch(kind, Run { seed })?;
        println!(
            "| {:<9} | {} | {} | {:.2}% | {} | {} | {} | {} | {} | {} | {:.0} | {:.0} |",
            row.name,
            row.ops,
            row.lost,
            row.lost_pct,
            row.false_conc,
            row.true_conc,
            row.max_siblings,
            row.metadata,
            row.get_p50,
            row.put_p50,
            row.wall_ms,
            row.sim_throughput,
        );
        // the paper's claims, enforced:
        if kind.is_lossless() {
            assert_eq!(row.lost, 0, "{} must be lossless", row.name);
        } else {
            assert!(row.lost > 0, "{} must lose concurrent updates", row.name);
        }
    }
    println!("\ncluster_sim OK — lossless mechanisms lost 0 updates; total-order/plausible baselines lost >0");
    Ok(())
}
