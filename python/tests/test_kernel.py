"""Pallas kernel vs jnp ref vs explicit set oracle — the CORE correctness
signal for L1 (see DESIGN.md E-index).

hypothesis sweeps random encoded-clock batches; fixed cases pin the paper's
own examples (Section 5.1 / 5.2).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, HealthCheck

from compile.kernels import ref
from compile.kernels import dominance as dk
from compile.kernels import vv_merge as mk
from tests import oracle
from tests.strategies import clock_batch, pad_batch

R = 8
SETTINGS = dict(
    max_examples=60,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def row(vv, dot=None):
    """Encode a clock row: vv list of len R, dot (slot, n) or None."""
    tail = [-1, 0] if dot is None else [dot[0], dot[1]]
    return np.array(list(vv) + tail, dtype=np.int32)


def empty_pad(n):
    return pad_batch(np.zeros((0, R + 2), np.int32), n, R)


class TestPaperExamples:
    """The concrete clocks the paper uses in Sections 5.1-5.3."""

    def test_section_5_2_concurrent_same_replica(self):
        # {(r,4)} || {(r,3,5)}: histories {r1..r4} || {r1,r2,r3,r5}.
        a = row([4, 0, 0, 0, 0, 0, 0, 0])
        b = row([3, 0, 0, 0, 0, 0, 0, 0], dot=(0, 5))
        assert oracle.code(a, b, R) == 0
        codes = ref.dominance(jnp.array([a]), jnp.array([b]), R)
        assert int(codes[0, 0]) == 0

    def test_section_5_1_dot_merges_into_range(self):
        # {(a,2),(b,1),(c,3,7)} represents {a1,a2,b1,c1,c2,c3,c7};
        # {(a,2),(b,1),(c,7)} (contiguous) strictly dominates it.
        dotted = row([2, 1, 3, 0, 0, 0, 0, 0], dot=(2, 7))
        full = row([2, 1, 7, 0, 0, 0, 0, 0])
        assert oracle.code(dotted, full, R) == 1
        assert oracle.code(full, dotted, R) == 2

    def test_contiguous_dot_equals_range(self):
        # (r, m, m+1) has the same history as (r, m+1).
        dotted = row([3, 0, 0, 0, 0, 0, 0, 0], dot=(0, 4))
        rng = row([4, 0, 0, 0, 0, 0, 0, 0])
        assert oracle.code(dotted, rng, R) == 3
        codes = ref.dominance(jnp.array([dotted]), jnp.array([rng]), R)
        assert int(codes[0, 0]) == 3

    def test_fig7_final_state(self):
        # z = {(a,0,3),(b,2)} vs y = (a,1,2): concurrent (Fig. 7).
        z = row([0, 2, 0, 0, 0, 0, 0, 0], dot=(0, 3))
        y = row([1, 0, 0, 0, 0, 0, 0, 0], dot=(0, 2))
        assert oracle.code(z, y, R) == 0
        # z dominates v=(b,0,1) and w=(b,0,2).
        v = row([0, 0, 0, 0, 0, 0, 0, 0], dot=(1, 1))
        w = row([0, 0, 0, 0, 0, 0, 0, 0], dot=(1, 2))
        assert oracle.code(v, z, R) == 1
        assert oracle.code(w, z, R) == 1


class TestRefVsOracle:
    """jnp ref == explicit event-set oracle."""

    @settings(**SETTINGS)
    @given(a=clock_batch(R, max_rows=8), b=clock_batch(R, max_rows=8))
    def test_dominance_codes(self, a, b):
        codes = np.array(ref.dominance(jnp.array(a), jnp.array(b), R))
        for i in range(a.shape[0]):
            for j in range(b.shape[0]):
                assert codes[i, j] == oracle.code(a[i], b[j], R), (
                    a[i], b[j])

    @settings(**SETTINGS)
    @given(a=clock_batch(R, max_rows=6), b=clock_batch(R, max_rows=6))
    def test_bulk_sync_masks(self, a, b):
        ka, kb, _ = ref.bulk_sync_masks(jnp.array(a), jnp.array(b), R)
        oka, okb = oracle.sync(a, b, R)
        assert [bool(x) for x in np.array(ka)] == oka
        assert [bool(x) for x in np.array(kb)] == okb


class TestPallasVsRef:
    """Pallas kernel output is bit-identical to the jnp ref."""

    @settings(**SETTINGS)
    @given(a=clock_batch(R, max_rows=16), b=clock_batch(R, max_rows=16))
    def test_dominance_tiled(self, a, b):
        ap = pad_batch(a, 64, R)
        bp = pad_batch(b, 64, R)
        got = np.array(dk.dominance(jnp.array(ap), jnp.array(bp), r=R))
        want = np.array(ref.dominance(jnp.array(ap), jnp.array(bp), R))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("n,m,tn,tm", [
        (64, 64, 64, 64),
        (128, 64, 64, 64),
        (128, 128, 32, 64),
        (64, 192, 64, 64),
    ])
    def test_grid_shapes(self, n, m, tn, tm):
        rng = np.random.default_rng(7)
        a = rng.integers(0, 5, size=(n, R + 2)).astype(np.int32)
        b = rng.integers(0, 5, size=(m, R + 2)).astype(np.int32)
        # force valid dot encoding
        for x in (a, b):
            has = x[:, R] % 2 == 0
            x[:, R] = np.where(has, x[:, R] % R, -1)
            sl = np.clip(x[:, R], 0, R - 1)
            m_at = x[np.arange(x.shape[0]), sl]
            x[:, R + 1] = np.where(has, m_at + 1 + x[:, R + 1], 0)
        got = np.array(dk.dominance(jnp.array(a), jnp.array(b), r=R, tn=tn, tm=tm))
        want = np.array(ref.dominance(jnp.array(a), jnp.array(b), R))
        np.testing.assert_array_equal(got, want)

    def test_padding_rows_are_harmless(self):
        # Pad rows must never cause a real row to be dropped.
        a = np.stack([row([2, 1, 0, 0, 0, 0, 0, 0], dot=(0, 3))])
        b = np.stack([row([0, 1, 0, 0, 0, 0, 0, 0], dot=(1, 2))])
        ap, bp = pad_batch(a, 64, R), pad_batch(b, 64, R)
        codes = np.array(dk.dominance(jnp.array(ap), jnp.array(bp), r=R))
        keep_a = ~np.any(codes == 1, axis=1)
        keep_b = ~np.any((codes & 2) != 0, axis=0)
        assert keep_a[0] and keep_b[0]  # concurrent reals both kept


class TestVvMerge:
    @settings(**SETTINGS)
    @given(a=clock_batch(R, min_rows=4, max_rows=16))
    def test_merge_is_max(self, a):
        vv = pad_batch(a, 256, R)[:, :R].copy()
        other = vv[::-1].copy()
        got = np.array(mk.vv_merge(jnp.array(vv), jnp.array(other)))
        np.testing.assert_array_equal(got, np.maximum(vv, other))

    def test_merge_join_laws(self):
        rng = np.random.default_rng(3)
        x = rng.integers(0, 9, size=(256, R)).astype(np.int32)
        y = rng.integers(0, 9, size=(256, R)).astype(np.int32)
        xy = np.array(mk.vv_merge(jnp.array(x), jnp.array(y)))
        yx = np.array(mk.vv_merge(jnp.array(y), jnp.array(x)))
        np.testing.assert_array_equal(xy, yx)        # commutative
        xx = np.array(mk.vv_merge(jnp.array(x), jnp.array(x)))
        np.testing.assert_array_equal(xx, x)         # idempotent
