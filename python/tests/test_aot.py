"""AOT pipeline sanity: lowering emits parseable HLO text + manifest."""

import os
import subprocess
import sys

from compile import aot


def test_lower_bulk_sync_small():
    text = aot.lower_bulk_sync(64, 64, 8)
    assert "HloModule" in text
    # interpret-mode pallas must lower to plain HLO (no mosaic custom-call)
    assert "mosaic" not in text.lower()


def test_lower_vv_merge():
    text = aot.lower_vv_merge(1024, 8)
    assert "HloModule" in text
    assert "maximum" in text


def test_artifacts_dir_matches_manifest(tmp_path=None):
    # When artifacts/ exists (built by make artifacts), every manifest entry
    # must point at an existing file.
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.txt")
    if not os.path.exists(manifest):
        return  # artifacts not built in this checkout; covered by make test
    for line in open(manifest):
        parts = line.split()
        assert len(parts) == 6, line
        assert os.path.exists(os.path.join(art, parts[5])), line
