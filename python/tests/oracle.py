"""Explicit set-based causal-history oracle (the paper's Section 3 formalism).

Decodes encoded clock rows into literal event sets and computes order by
set inclusion — the trusted reference that both the jnp ref and the Pallas
kernel must match.
"""

from __future__ import annotations


def history(row, r: int) -> frozenset:
    """C[[row]]: the causal history of an encoded clock row."""
    events = set()
    for slot in range(r):
        for i in range(1, int(row[slot]) + 1):
            events.add((slot, i))
    if int(row[r]) >= 0:
        events.add((int(row[r]), int(row[r + 1])))
    return frozenset(events)


def leq(row_a, row_b, r: int) -> bool:
    """A <= B iff C[[A]] subset-of C[[B]]."""
    return history(row_a, r) <= history(row_b, r)


def code(row_a, row_b, r: int) -> int:
    """(B<=A) << 1 | (A<=B), the kernel's dominance code."""
    ab = leq(row_a, row_b, r)
    ba = leq(row_b, row_a, r)
    return (int(ba) << 1) | int(ab)


def sync(set_a, set_b, r: int):
    """The paper's sync over decoded clock sets, as (keep_a, keep_b) masks.

    keep_a[i]: A_i not strictly dominated by any B_j.
    keep_b[j]: B_j not dominated-or-equal by any A_i.
    """
    keep_a = [
        not any(code(a, b, r) == 1 for b in set_b) for a in set_a
    ]
    keep_b = [
        not any(code(a, b, r) & 2 for a in set_a) for b in set_b
    ]
    return keep_a, keep_b
