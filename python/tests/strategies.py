"""Hypothesis strategies for encoded clocks."""

from __future__ import annotations

import numpy as np
from hypothesis import strategies as st


@st.composite
def clock_row(draw, r: int, max_counter: int = 6):
    """A single encoded clock row i32[R+2].

    Dots respect the invariant n > m for the dot slot (Section 5.1:
    "in a component (r, m, n) we will always have n > m"); dotless rows
    carry (-1, 0).
    """
    vv = [draw(st.integers(0, max_counter)) for _ in range(r)]
    with_dot = draw(st.booleans())
    if with_dot:
        slot = draw(st.integers(0, r - 1))
        n = draw(st.integers(vv[slot] + 1, vv[slot] + 1 + max_counter))
        tail = [slot, n]
    else:
        tail = [-1, 0]
    return np.array(vv + tail, dtype=np.int32)


@st.composite
def clock_batch(draw, r: int, min_rows: int = 1, max_rows: int = 16):
    rows = draw(st.lists(clock_row(r), min_size=min_rows, max_size=max_rows))
    return np.stack(rows)


def pad_batch(batch: np.ndarray, to: int, r: int) -> np.ndarray:
    """Pad with empty rows (all-zero vv, dot slot -1) to `to` rows."""
    pad = np.zeros((to - batch.shape[0], r + 2), dtype=np.int32)
    pad[:, r] = -1
    return np.concatenate([batch, pad], axis=0)
