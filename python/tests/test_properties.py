"""Order-theoretic properties of the dominance relation (hypothesis).

The dotted-clock order must be a partial order on histories; these
properties catch any divergence between the vectorized math and the
set-inclusion semantics.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, HealthCheck

from compile.kernels import ref
from tests import oracle
from tests.strategies import clock_row

R = 8
SETTINGS = dict(
    max_examples=80,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def _code(a, b):
    return int(np.array(ref.dominance(jnp.array([a]), jnp.array([b]), R))[0, 0])


@settings(**SETTINGS)
@given(a=clock_row(R))
def test_reflexive(a):
    assert _code(a, a) == 3


@settings(**SETTINGS)
@given(a=clock_row(R), b=clock_row(R))
def test_antisymmetric_on_histories(a, b):
    if _code(a, b) == 3:
        assert oracle.history(a, R) == oracle.history(b, R)


@settings(**SETTINGS)
@given(a=clock_row(R), b=clock_row(R), c=clock_row(R))
def test_transitive(a, b, c):
    if _code(a, b) & 1 and _code(b, c) & 1:
        assert _code(a, c) & 1


@settings(**SETTINGS)
@given(a=clock_row(R), b=clock_row(R))
def test_code_symmetry(a, b):
    ab, ba = _code(a, b), _code(b, a)
    assert ab == ((ba & 1) << 1 | (ba >> 1))
