"""L2: the JAX compute graph for bulk anti-entropy sync.

``bulk_sync`` is the store's bulk compute: given two encoded clock sets
(one local, one received from a peer replica), compute the pairwise
dominance matrix with the L1 Pallas kernel and reduce it to the keep-masks
realizing the paper's sync(S1, S2) (Section 4) over the whole batch at
once. The rust coordinator (rust/src/antientropy) calls the AOT-compiled
artifact of this function on its request path; python never runs there.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels import dominance as dom_kernel
from compile.kernels import vv_merge as merge_kernel


def bulk_sync(a, b, *, r: int, tn: int = 64, tm: int = 64):
    """sync(S1, S2) keep-masks over encoded clock batches.

    Inputs: ``a`` i32[N, R+2], ``b`` i32[M, R+2] (padded; empty rows are
    all-zero vv with dot slot -1 and must not encode real versions).
    Returns ``(keep_a i32[N], keep_b i32[M], codes i32[N, M])``; see
    ``kernels.ref.bulk_sync_masks`` for the reduction contract.
    """
    codes = dom_kernel.dominance(a, b, r=r, tn=tn, tm=tm)
    keep_a = jnp.logical_not(jnp.any(codes == 1, axis=1)).astype(jnp.int32)
    keep_b = jnp.logical_not(jnp.any((codes & 2) != 0, axis=0)).astype(jnp.int32)
    return keep_a, keep_b, codes


def dominance_only(a, b, *, r: int, tn: int = 64, tm: int = 64):
    """Raw dominance-code matrix (read-repair classification path)."""
    return (dom_kernel.dominance(a, b, r=r, tn=tn, tm=tm),)


def vv_merge(a, b, *, tb: int = 256):
    """Pointwise version-vector join of two i32[B, R] batches."""
    return (merge_kernel.vv_merge(a, b, tb=tb),)
