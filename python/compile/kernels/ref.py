"""Pure-jnp reference oracle for the clock kernels.

This module is the *correctness contract* for the Pallas kernels in
``dominance.py`` / ``vv_merge.py``: identical math, expressed as plain
``jax.numpy`` ops with no pallas involvement. ``python/tests`` asserts the
Pallas kernels agree with these functions bit-for-bit, and that both agree
with an explicit set-based causal-history oracle (``tests/oracle.py``).

Clock tensor encoding (the shared python <-> rust contract, see DESIGN.md S2):

  row = i32[W], W = R + 2
    row[0..R-1]  per-replica-slot contiguous range max ("(r, m)" components)
    row[R]       dot slot index, or -1 when the clock carries no dot
    row[R+1]     dot event number n (for "(r, m, n)"), 0 when no dot

The represented causal history is
  C[[row]] = union_i { slot_i events 1..row[i] }  u  { dot event }

Dominance X <= Y is causal-history inclusion, evaluated per DESIGN.md S2:

  range_i(X) subset C[Y]  iff  vvx[i] <= vvy[i]
                            or (sy == i and ny == vvy[i]+1 and vvx[i] <= ny)
  dot(X) in C[Y]          iff  nx <= vvy[sx]  or  (sy == sx and ny == nx)
"""

from __future__ import annotations

import jax.numpy as jnp


def split(clock_mat: jnp.ndarray, r: int):
    """Split an encoded clock matrix [B, R+2] into (vv, dot_slot, dot_n)."""
    return clock_mat[:, :r], clock_mat[:, r], clock_mat[:, r + 1]


def leq_matrix(a: jnp.ndarray, b: jnp.ndarray, r: int) -> jnp.ndarray:
    """Pairwise dominance: out[i, j] = (A_i <= B_j), boolean [N, M].

    ``a``: i32[N, R+2] encoded clocks; ``b``: i32[M, R+2].
    """
    vvx, sx, nx = split(a, r)  # [N,R], [N], [N]
    vvy, sy, ny = split(b, r)  # [M,R], [M], [M]

    n_, m_ = vvx.shape[0], vvy.shape[0]
    # Broadcast layout: [N, M, R]
    vvx_b = vvx[:, None, :]
    vvy_b = vvy[None, :, :]
    sy_b = sy[None, :, None]
    ny_b = ny[None, :, None]
    slot = jnp.arange(r, dtype=a.dtype)[None, None, :]

    # Y's coverage of slot i is 1..vvy[i], plus ny iff it extends the range
    # contiguously (ny == vvy[i] + 1). A hole (ny > vvy[i]+1) does not help
    # a contiguous range from X.
    dot_extends = (sy_b == slot) & (ny_b == vvy_b + 1)
    range_ok = (vvx_b <= vvy_b) | (dot_extends & (vvx_b <= ny_b))
    ranges_ok = jnp.all(range_ok, axis=-1)  # [N, M]

    # X's dot (if any) must be in C[Y]: nx <= vvy[sx]  or  Y's dot equals it.
    has_dot = sx >= 0  # [N]
    # vvy_at_sx[i, j] = vvy[j, sx[i]] without gather: one-hot mask + reduce.
    onehot_sx = (jnp.arange(r, dtype=a.dtype)[None, :] == sx[:, None])  # [N,R]
    vvy_at_sx = jnp.max(
        jnp.where(onehot_sx[:, None, :], vvy_b, jnp.zeros_like(vvy_b)),
        axis=-1,
    )  # [N, M]
    dot_in_range = nx[:, None] <= vvy_at_sx
    dot_matches = (sy[None, :] == sx[:, None]) & (ny[None, :] == nx[:, None])
    dot_ok = jnp.where(has_dot[:, None], dot_in_range | dot_matches,
                       jnp.ones((n_, m_), dtype=jnp.bool_))

    return ranges_ok & dot_ok


def dominance(a: jnp.ndarray, b: jnp.ndarray, r: int) -> jnp.ndarray:
    """Pairwise dominance codes: i32[N, M].

    code = (B_j <= A_i) << 1 | (A_i <= B_j):
      0 concurrent, 1 strictly less, 2 strictly greater, 3 equal histories.
    """
    leq_ab = leq_matrix(a, b, r)
    leq_ba = leq_matrix(b, a, r).T
    return (leq_ba.astype(jnp.int32) << 1) | leq_ab.astype(jnp.int32)


def bulk_sync_masks(a: jnp.ndarray, b: jnp.ndarray, r: int):
    """The paper's sync(S1, S2) over encoded clock sets, as keep-masks.

    Returns (keep_a i32[N], keep_b i32[M], codes i32[N, M]).
    An A-row is kept unless strictly dominated by some B-row; a B-row is
    kept unless dominated-or-equal by some A-row (equal pairs keep the A
    copy so the union contains one representative). Rows within each input
    set are assumed already mutually concurrent (store invariant).
    """
    codes = dominance(a, b, r)
    # A_i dropped iff exists j with code == 1 (A_i < B_j).
    keep_a = jnp.logical_not(jnp.any(codes == 1, axis=1)).astype(jnp.int32)
    # B_j dropped iff exists i with bit1 set (B_j <= A_i).
    keep_b = jnp.logical_not(jnp.any((codes & 2) != 0, axis=0)).astype(jnp.int32)
    return keep_a, keep_b, codes


def vv_merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Pointwise join of version-vector batches: i32[B, R] max."""
    return jnp.maximum(a, b)
