"""L1 Pallas kernel: batched pointwise version-vector join (max-merge).

Used by read repair and anti-entropy digest merging: joins two batches of
plain version vectors slot-by-slot. Trivially memory-bound; it exists to
exercise the multi-artifact AOT pipeline and serves as the merge stage of
the bulk anti-entropy path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _merge_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.maximum(a_ref[...], b_ref[...])


def vv_merge(a, b, *, tb: int = 256):
    """Pointwise max of i32[B, R] batches via Pallas (interpret mode)."""
    bsz, r = a.shape
    assert a.shape == b.shape
    assert bsz % tb == 0, (bsz, tb)
    return pl.pallas_call(
        _merge_kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, r), jnp.int32),
        grid=(bsz // tb,),
        in_specs=[
            pl.BlockSpec((tb, r), lambda i: (i, 0)),
            pl.BlockSpec((tb, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tb, r), lambda i: (i, 0)),
        interpret=True,
    )(a, b)
