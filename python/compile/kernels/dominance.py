"""L1 Pallas kernel: batched pairwise dotted-version-vector dominance.

The anti-entropy hot spot of the store: given two sets of encoded clocks
(see ``ref.py`` for the encoding contract), produce the pairwise dominance
code matrix. The L2 model (``model.py``) reduces this matrix into the
keep-masks implementing the paper's ``sync`` over whole key ranges.

TPU mapping (DESIGN.md "Hardware adaptation"): the grid tiles the N x M
dominance matrix; each step streams one (TN, W) strip of A and one (TM, W)
strip of B from HBM into VMEM and writes a (TN, TM) tile of codes. The body
is integer compare + logical-reduce over the W axis (VPU work, not MXU).
``interpret=True`` is mandatory here: the CPU PJRT client cannot execute
Mosaic custom-calls, and interpret-mode lowering produces plain HLO the
rust runtime can compile (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _leq_block(a_blk, b_blk, r: int):
    """(A_i <= B_j) over a (TN, W) x (TM, W) tile -> bool (TN, TM).

    Same math as ``ref.leq_matrix`` (the correctness contract), written
    block-local so it can run inside a pallas kernel body.
    """
    vvx, sx, nx = a_blk[:, :r], a_blk[:, r], a_blk[:, r + 1]
    vvy, sy, ny = b_blk[:, :r], b_blk[:, r], b_blk[:, r + 1]

    tn, tm = vvx.shape[0], vvy.shape[0]
    vvx_b = vvx[:, None, :]          # [TN, 1, R]
    vvy_b = vvy[None, :, :]          # [1, TM, R]
    sy_b = sy[None, :, None]
    ny_b = ny[None, :, None]
    slot = jax.lax.broadcasted_iota(a_blk.dtype, (1, 1, r), dimension=2)

    dot_extends = (sy_b == slot) & (ny_b == vvy_b + 1)
    range_ok = (vvx_b <= vvy_b) | (dot_extends & (vvx_b <= ny_b))
    ranges_ok = jnp.all(range_ok, axis=-1)                     # [TN, TM]

    has_dot = sx >= 0
    slot_row = jax.lax.broadcasted_iota(a_blk.dtype, (tn, r), dimension=1)
    onehot_sx = slot_row == sx[:, None]                        # [TN, R]
    vvy_at_sx = jnp.max(
        jnp.where(onehot_sx[:, None, :], vvy_b, jnp.zeros_like(vvy_b)),
        axis=-1,
    )                                                          # [TN, TM]
    dot_in_range = nx[:, None] <= vvy_at_sx
    dot_matches = (sy[None, :] == sx[:, None]) & (ny[None, :] == nx[:, None])
    dot_ok = jnp.where(has_dot[:, None], dot_in_range | dot_matches,
                       jnp.ones((tn, tm), dtype=jnp.bool_))
    return ranges_ok & dot_ok


def _dominance_kernel(a_ref, b_ref, o_ref, *, r: int):
    """Pallas body: codes tile = (B<=A) << 1 | (A<=B)."""
    a_blk = a_ref[...]
    b_blk = b_ref[...]
    leq_ab = _leq_block(a_blk, b_blk, r)
    leq_ba = _leq_block(b_blk, a_blk, r).T
    o_ref[...] = (leq_ba.astype(jnp.int32) << 1) | leq_ab.astype(jnp.int32)


def dominance(a, b, *, r: int, tn: int = 64, tm: int = 64):
    """Pairwise dominance codes i32[N, M] via the tiled Pallas kernel.

    ``a``: i32[N, R+2], ``b``: i32[M, R+2]. N % tn == 0 and M % tm == 0 is
    required; callers pad with empty rows (all-zero vv, slot -1) and slice.
    """
    n, w = a.shape
    m, _ = b.shape
    assert w == r + 2, f"clock width {w} != R+2 for R={r}"
    assert n % tn == 0 and m % tm == 0, (n, m, tn, tm)
    grid = (n // tn, m // tm)
    return pl.pallas_call(
        functools.partial(_dominance_kernel, r=r),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, w), lambda i, j: (i, 0)),
            pl.BlockSpec((tm, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tn, tm), lambda i, j: (i, j)),
        interpret=True,
    )(a, b)
