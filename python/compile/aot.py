"""AOT driver: lower the L2 graphs to HLO *text* artifacts for rust/PJRT.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the published xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are shape-specialized; a manifest (artifacts/manifest.txt) lists
one entry per line:

    <kind> <name> <N> <M> <R> <file>

The rust runtime (rust/src/runtime/artifact.rs) parses the manifest, picks
the smallest variant that fits a request, and pads inputs.

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# (N, M) variants for bulk_sync / dominance; R fixed per variant.
SYNC_VARIANTS = [
    (64, 64, 8),
    (256, 256, 8),
    (1024, 1024, 8),
]
MERGE_VARIANTS = [
    (1024, 8),
    (4096, 8),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bulk_sync(n: int, m: int, r: int) -> str:
    spec_a = jax.ShapeDtypeStruct((n, r + 2), jnp.int32)
    spec_b = jax.ShapeDtypeStruct((m, r + 2), jnp.int32)
    tn = min(64, n)
    tm = min(64, m)
    fn = functools.partial(model.bulk_sync, r=r, tn=tn, tm=tm)
    return to_hlo_text(jax.jit(fn).lower(spec_a, spec_b))


def lower_vv_merge(b: int, r: int) -> str:
    spec = jax.ShapeDtypeStruct((b, r), jnp.int32)
    tb = min(256, b)
    fn = functools.partial(model.vv_merge, tb=tb)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for n, m, r in SYNC_VARIANTS:
        name = f"bulk_sync_{n}x{m}_r{r}"
        path = os.path.join(args.out_dir, name + ".hlo.txt")
        text = lower_bulk_sync(n, m, r)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"bulk_sync {name} {n} {m} {r} {name}.hlo.txt")
        print(f"wrote {path} ({len(text)} chars)")
    for b, r in MERGE_VARIANTS:
        name = f"vv_merge_{b}_r{r}"
        path = os.path.join(args.out_dir, name + ".hlo.txt")
        text = lower_vv_merge(b, r)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"vv_merge {name} {b} {b} {r} {name}.hlo.txt")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
